"""Paper Fig. 4: next-layer hidden-state cosine similarity, inter-expert
predictor recall, intra-expert predictor precision — on a trained small MoE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predictor, sparsify
from repro.core.pipeline import _unstack_layers
from repro.data import SyntheticLM, make_batches
from repro.models import blocks as blk
from repro.models import nn
from repro.models import transformer as tf
from repro.models.moe import router_topk


def _collect_layer_inputs(cfg, params, toks):
    """Hidden states entering each layer (the residual stream)."""
    x = jnp.take(params["embedding"], toks, axis=0)
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    hs = []
    layers = _unstack_layers(params, cfg)
    for layer in layers:
        hs.append(x.reshape(-1, d))
        kind = "moe" if "moe" in layer else "dense"
        x, _ = blk.block_forward(layer, kind, x, positions, cfg)
    hs.append(x.reshape(-1, d))
    return hs, layers


_CACHE = {}


def deep_trained_model(layers=6, steps=200):
    """Obs. 3 (hidden-state similarity) is a DEPTH phenomenon — per-layer
    updates shrink relative to the residual stream as depth grows — so the
    predictor benchmark uses a deeper, thinner MoE than the other benches."""
    if "m" in _CACHE:
        return _CACHE["m"]
    from repro.common.config import TrainConfig, reduced
    from repro.configs import get_config
    from repro.launch.train import train_loop
    cfg = reduced(get_config("mixtral_8x7b"), layers=layers, d_model=96)
    tc = TrainConfig(learning_rate=2e-3, total_steps=steps,
                     warmup_steps=steps // 10)
    params, _, _ = train_loop(cfg, tc, batch=8, seq=64, steps=steps,
                              log_every=10**9)
    _CACHE["m"] = (cfg, params)
    return cfg, params


def run(csv_rows: list):
    cfg, params = deep_trained_model()
    src = SyntheticLM(cfg.vocab_size, seed=55)
    batch = next(make_batches(src, 8, 64, 1, seed=55))
    toks = jnp.asarray(batch["tokens"][:, :64])
    hs, layers = _collect_layer_inputs(cfg, params, toks)

    k = cfg.num_experts_per_tok
    sims, recalls, intra_recalls = [], [], []
    for li in range(len(layers) - 1):
        h_i, h_next = hs[li], hs[li + 1]
        sims.append(float(predictor.cosine_similarity(h_i, h_next)))
        nxt = layers[li + 1]
        if "moe" not in nxt:
            continue
        hn_norm = nn.rms_norm(h_next, nxt["mlp_norm"]["scale"], cfg.norm_eps)
        _, true_ids, _ = router_topk(hn_norm, nxt["moe"]["router"], k)
        # inter: train a small MLP on half the trace, eval on the other half
        t_half = h_i.shape[0] // 2
        targets = jax.nn.one_hot(true_ids, cfg.num_experts).sum(1)
        ip = predictor.init_inter_predictor(
            jax.random.PRNGKey(li), cfg.d_model, cfg.num_experts, hidden=64)
        ip = predictor.train_inter_predictor(
            ip, h_i[:t_half], targets[:t_half], steps=200)
        pred = predictor.inter_predict_topk(ip, h_i[t_half:], k)
        recalls.append(float(predictor.recall_at_k(pred, true_ids[t_half:])))
        # intra: reuse-based mask prediction for the top-used expert
        e = int(jnp.bincount(true_ids.reshape(-1),
                             length=cfg.num_experts).argmax())
        w_up = nxt["moe"]["we_up"][e]
        v_true = hn_norm @ w_up
        t = jnp.quantile(jnp.abs(v_true), cfg.floe.sparsity)
        true_mask = jnp.abs(v_true) >= t
        h_i_norm = nn.rms_norm(h_i, nxt["mlp_norm"]["scale"], cfg.norm_eps)
        pred_mask = predictor.intra_predict_mask(h_i_norm, w_up, t)
        _, rec = predictor.mask_precision_recall(pred_mask, true_mask)
        intra_recalls.append(float(rec))

    per_layer = " ".join(f"{s:.3f}" for s in sims)
    csv_rows.append(("fig4/next_layer_cosine_mean", 0.0,
                     f"{np.mean(sims):.4f} deep-half={np.mean(sims[len(sims)//2:]):.4f} "
                     f"per-layer=[{per_layer}] (paper: >0.95 on 32L Mixtral; "
                     "similarity grows with depth — Fig. 4's layer-0 outlier "
                     "is our every-layer regime at 6L)"))
    if recalls:
        csv_rows.append(("fig4/inter_predictor_recall", 0.0,
                         f"mean={np.mean(recalls):.4f} "
                         f"deep-half={np.mean(recalls[len(recalls)//2:]):.4f} "
                         "(paper ~0.88 precision)"))
    if intra_recalls:
        csv_rows.append(("fig4/intra_predictor_recall", 0.0,
                         f"mean={np.mean(intra_recalls):.4f} "
                         f"deep-half={np.mean(intra_recalls[len(intra_recalls)//2:]):.4f} "
                         "(paper ~0.95)"))
