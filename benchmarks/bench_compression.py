"""Paper headline: per-expert compression ratio (9.3x on Mixtral-8x7B) and
memory-footprint reduction (deployable in 11GB VRAM, up to 8.5x).

Computed analytically from the real Mixtral-8x7B config + our HQQ storage
format, and empirically on a small expert tensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import hqq


def expert_bytes(cfg, *, sparsity: float, up_bits: int, group: int,
                 scale_bytes: int = 2) -> tuple[int, int]:
    d, f = cfg.d_model, cfg.moe_d_ff
    dense = 3 * d * f * 2  # fp16
    keep = 1.0 - sparsity
    gate_down = int(2 * d * f * keep) * 2
    up = d * f * up_bits // 8 + 2 * (d // group) * f * scale_bytes
    return dense, gate_down + up


def run(csv_rows: list):
    cfg = get_config("mixtral_8x7b")
    for sp in (0.8, 0.9):
        dense, comp = expert_bytes(cfg, sparsity=sp, up_bits=2, group=64)
        csv_rows.append((f"headline/per_expert_compression@{sp:.0%}", 0.0,
                         f"{dense / comp:.2f}x (paper: 9.3x; dense="
                         f"{dense / 2**20:.0f}MiB comp={comp / 2**20:.1f}MiB)"))

    # whole-model footprint: resident = non-expert + quantized up (for the
    # intra predictor) + cache of `slots` compressed experts per layer
    d, f, L, E = cfg.d_model, cfg.moe_d_ff, cfg.num_layers, cfg.num_experts
    non_expert = (cfg.param_count() - L * E * 3 * d * f) * 2
    up_all = L * E * (d * f * 2 // 8 + 2 * (d // 64) * f * 2)
    _, comp = expert_bytes(cfg, sparsity=0.9, up_bits=2, group=64)
    cache = L * 2 * int(0.1 * 2 * d * f) * 2  # 2 slots of sparse gate/down
    total = non_expert + up_all + cache
    full = cfg.param_count() * 2
    csv_rows.append(("headline/vram_floe_gb", 0.0,
                     f"{total / 2**30:.2f}GiB (paper: fits 11GB VRAM)"))
    csv_rows.append(("headline/vram_reduction", 0.0,
                     f"{full / total:.2f}x vs fp16-resident "
                     f"{full / 2**30:.1f}GiB (paper: up to 8.5x)"))

    # empirical packed sizes round-trip on a real tensor
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 1792)) * 0.02
    qt = hqq.quantize(w, bits=2, group=64)
    csv_rows.append(("headline/int2_tensor_ratio", 0.0,
                     f"{hqq.compression_ratio(w, qt):.2f}x "
                     f"rel_err={hqq.rel_error(w, qt):.3f}"))
