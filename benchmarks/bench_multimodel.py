"""Multi-model serving benchmark: one shared host tier vs isolation.

The ``repro.deploy`` fleet serves several models over ONE
HostTier/DiskTier (global hottest-first warming, per-model key
prefixes) with disjoint per-device arenas.  The claim to pin:

* **stall/token is NO WORSE** than running the same two models as two
  fully isolated deployments (each with its own host tier), because
  the shared LRU keeps both models' HOT records resident and decode
  never reaches the evicted cold tail; and
* **host bytes are STRICTLY LOWER**, because the shared tier is
  provisioned below the sum of the two isolated tiers and the cold
  tail of the union is simply not resident.

Both regimes decode identical token streams through identical plans
(``plan_cluster`` at one device reproduces ``plan_store`` exactly, and
the n=1 cluster shim is timeline-identical to the plain runtime — both
pinned by tests), with prefetch and progressive refinement disabled so
the link is drained between steps and the comparison isolates the host
tier.  The decode is interleaved token-by-token across the two models —
the fleet's lockstep-clock regime — so any cross-model contention on
the shared link would show up as stall.
"""
from __future__ import annotations

import numpy as np

from repro.deploy import (DeploymentSpec, ModelSpec, ResourceSpec,
                          RuntimeSpec, build, build_fleet)
from repro.store import floor_bytes
from repro.store import formats as F

TOKENS = 6
BATCH = 1
ALPHA = 0.9
SEEDS = (0, 1)
#: the shared tier is provisioned at this fraction of the two isolated
#: tiers' total — the strictly-lower-bytes claim under test
SHARED_FRACTION = 0.8
_CACHE: dict = {}


def _spec(name: str, seed: int, vram_gb: float, host_gb: float
          ) -> DeploymentSpec:
    return DeploymentSpec(
        name=name,
        model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=128,
                        max_experts=8, seed=seed),
        resources=ResourceSpec(vram_gb=vram_gb, host_gb=host_gb,
                               ladder=("int2",), progressive=False),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False))


def _setup():
    if "setup" in _CACHE:
        return _CACHE["setup"]
    probe = _spec("probe", 0, 1.0, 1.0)
    cfg = probe.resolve_config()
    vram_gb = 1.2 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    # one model's record bytes (formats are budget-determined, so any
    # seed's plan sizes the records identically)
    from repro.deploy.builder import plan_resources, resolve_params
    from repro.core.pipeline import _unstack_layers
    s0 = _spec("probe", 0, vram_gb, 1.0)
    params = resolve_params(s0.model, cfg)
    plan, _ = plan_resources(s0, cfg, _unstack_layers(params, cfg))
    rec_bytes = sum(
        F.host_bytes(F.get_format(name), cfg.d_model, cfg.moe_d_ff)
        for name in plan.formats.values())
    _CACHE["setup"] = (cfg, vram_gb, rec_bytes)
    return _CACHE["setup"]


def _h_streams(cfg):
    import jax
    import jax.numpy as jnp
    streams = {}
    for name, seed in zip("ab", SEEDS):
        key = jax.random.PRNGKey(1000 + seed)
        hs = []
        h = jax.random.normal(key, (BATCH, cfg.d_model), jnp.float32)
        for _ in range(TOKENS):
            hs.append(h)
            key, sub = jax.random.split(key)
            n = jax.random.normal(sub, (BATCH, cfg.d_model), jnp.float32)
            h = ALPHA * h + (1 - ALPHA ** 2) ** 0.5 * n
        streams[name] = hs
    return streams


def _stream_freqs(spec: DeploymentSpec, stream, cfg) -> np.ndarray:
    """Measured (L, E) activation frequencies of THIS decode stream: a
    throwaway deployment decodes it once with the router instrumented.
    Both regimes then plan and warm from the same measured temperatures
    (the production analogue: plan from the traffic you actually serve,
    not from a synthetic proxy)."""
    dep = build(spec)
    counts = np.zeros((cfg.num_layers, cfg.num_experts), np.float64)
    route = dep.pipeline._route

    def counting_route(h, li):
        gates, eids, probs = route(h, li)
        ids, n = np.unique(np.asarray(eids).reshape(-1), return_counts=True)
        counts[li, ids] += n
        return gates, eids, probs

    dep.pipeline._route = counting_route
    for h in stream:
        dep.generate(1, h_stream=[h])
    sums = counts.sum(axis=1, keepdims=True)
    return counts / np.maximum(sums, 1.0)


def run(csv_rows: list):
    cfg, vram_gb, rec_bytes = _setup()
    iso_host_gb = 1.05 * rec_bytes / 2 ** 30  # each isolated tier: ALL
    #                                           of its model resident
    shared_gb = SHARED_FRACTION * 2 * iso_host_gb
    streams = _h_streams(cfg)
    freqs = {name: _stream_freqs(_spec(name, seed, vram_gb, iso_host_gb),
                                 streams[name], cfg)
             for name, seed in zip("ab", SEEDS)}

    # ---- regime A: two fully isolated deployments ------------------------
    iso_stall = iso_bytes = 0.0
    for name, seed in zip("ab", SEEDS):
        dep = build(_spec(name, seed, vram_gb, iso_host_gb),
                    freqs=freqs[name])
        for h in streams[name]:
            dep.generate(1, h_stream=[h])
        iso_stall += sum(m.stall_s for m in dep.pipeline.metrics)
        iso_bytes += dep.pipeline.host_tier.bytes_in_use
    iso_stall_tok = iso_stall / (2 * TOKENS)

    # ---- regime B: one fleet over a SHARED host/disk tier ----------------
    # each member promises (and is admitted for) half the shared tier
    member_gb = shared_gb / 2
    fleet = build_fleet(
        [_spec(name, seed, vram_gb, member_gb)
         for name, seed in zip("ab", SEEDS)],
        vram_gb_per_device=2.5 * vram_gb, host_gb=shared_gb,
        freqs=[freqs[n] for n in "ab"])
    for i in range(TOKENS):  # interleave: the multi-model serving regime
        for name in "ab":
            fleet.generate(name, 1, h_stream=[streams[name][i]])
    shared_stall = sum(
        m.stall_s for mem in fleet.members.values()
        for m in mem.deployment.pipeline.metrics)
    shared_stall_tok = shared_stall / (2 * TOKENS)
    rep = fleet.report()
    shared_bytes = rep["host_bytes_in_use"]
    decode_misses = fleet.host.stats.misses

    no_worse = shared_stall_tok <= iso_stall_tok + 1e-9
    strictly_lower = shared_bytes < iso_bytes

    csv_rows.append(("multimodel/stall_per_token_ms/isolated", 0.0,
                     f"{iso_stall_tok * 1e3:.4f}"))
    csv_rows.append(("multimodel/stall_per_token_ms/shared_tier", 0.0,
                     f"{shared_stall_tok * 1e3:.4f}"))
    csv_rows.append(("multimodel/host_bytes/isolated", 0.0,
                     f"{iso_bytes:.0f}"))
    csv_rows.append(("multimodel/host_bytes/shared_tier", 0.0,
                     f"{shared_bytes:.0f}"))
    csv_rows.append((
        "multimodel/shared_stall_no_worse", 0.0,
        f"{no_worse} ({shared_stall_tok * 1e3:.4f}ms vs "
        f"{iso_stall_tok * 1e3:.4f}ms; decode host misses="
        f"{decode_misses})"))
    csv_rows.append((
        "multimodel/host_bytes_strictly_lower", 0.0,
        f"{strictly_lower} ({shared_bytes / max(iso_bytes, 1):.2%} of "
        f"isolated; shared tier provisioned at {SHARED_FRACTION:.0%} "
        f"of the two isolated tiers)"))
    csv_rows.append((
        "multimodel/shared_tier", 0.0,
        f"hit_rate={rep['host_hit_rate']:.3f} "
        f"resident/model="
        f"{[rep['models'][n]['host_resident_bytes'] for n in 'ab']} "
        f"capacity={rep['host_capacity_bytes']}"))

    # ---- scenario-parameterized fleet serving ----------------------------
    # Both models of a serving fleet (shared tier, own control planes)
    # run the committed flash-crowd scenario: distinct seeds offset the
    # two tenants' burst traffic, per-model/per-tenant attainment rows.
    import dataclasses as _dc
    import os
    from repro.deploy import ServingSpec
    from repro.workload import ScenarioSpec
    scen = ScenarioSpec.load(os.path.join(
        os.path.dirname(__file__), os.pardir, "examples", "scenarios",
        "flash_crowd.json"))
    serve_fleet = build_fleet(
        [_dc.replace(_spec(name, seed, vram_gb, member_gb),
                     serving=ServingSpec(slots=2, max_len=128,
                                         online_train=False))
         for name, seed in zip("ab", SEEDS)],
        vram_gb_per_device=2.5 * vram_gb, host_gb=shared_gb,
        freqs=[freqs[n] for n in "ab"])
    for name, seed in zip("ab", SEEDS):
        serve_fleet.serve(name, scenario=_dc.replace(
            scen, seed=scen.seed + seed, n_requests=8))
        ctl = serve_fleet[name].deployment.controller
        rep = ctl.report()
        tenants = ctl.tenant_report()
        per_tenant = " ".join(
            f"{t}:{v['slo_attainment']:.0%}" for t, v in tenants.items())
        csv_rows.append((
            f"multimodel/scenario_fleet/{scen.name}/model={name}", 0.0,
            f"slo={rep['slo_attainment']:.0%} per_tenant=[{per_tenant}] "
            f"tps={rep['tokens_per_s']:.1f} rej={rep['rejected']} "
            f"(acceptance: both models complete the scenario)"))

    # admission telemetry: the same fleet rejects a third model (the
    # footprint-aware admission path exercised under bench conditions)
    from repro.deploy import AdmissionError
    try:
        build_fleet(
            [_spec(name, seed, vram_gb, member_gb)
             for name, seed in zip("abc", (0, 1, 2))],
            vram_gb_per_device=2.5 * vram_gb, host_gb=shared_gb)
        admitted = "ADMITTED (unexpected)"
    except AdmissionError as e:
        admitted = f"rejected: {e.field}"
    csv_rows.append(("multimodel/oversubscribed_third_model", 0.0,
                     admitted))
