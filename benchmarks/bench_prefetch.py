"""Runtime scheduler benchmark: overlap efficiency, prefetch precision /
recall, and modeled stall time per token — scheduler-driven decode vs the
synchronous ``core.pipeline`` accounting (FloE Fig. 1(c) made an event
loop).

Both paths use the SAME predictor (router reuse on the proxy hidden
state), so prediction accuracy is equal by construction; the delta comes
from the runtime's scheduling: cross-layer lookahead, cross-token
speculation, demand preemption, and issue-all-then-wait demand/compute
overlap within a layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import FloEPipeline, paper_scaled_models
from benchmarks.bench_e2e_decode import _thresholds


def _h_stream(cfg, steps: int, batch: int, alpha: float = 0.95, seed: int = 0):
    """Temporally correlated hidden-state stream: consecutive decode steps
    keep cosine similarity ~alpha (the premise behind FloE's reuse-based
    prediction, applied across tokens)."""
    key = jax.random.PRNGKey(seed)
    h = jax.random.normal(key, (batch, cfg.d_model), jnp.float32)
    out = [h]
    for _ in range(steps - 1):
        key, sub = jax.random.split(key)
        n = jax.random.normal(sub, (batch, cfg.d_model), jnp.float32)
        h = alpha * h + (1.0 - alpha ** 2) ** 0.5 * n
        out.append(h)
    return out


def _run(pipe, hs):
    for h in hs:
        pipe.decode_token(h)
    n = len(pipe.metrics)
    return {
        "stall_ms": sum(m.stall_s for m in pipe.metrics) / n * 1e3,
        "tps": pipe.tokens_per_second(),
        "coverage": float(np.mean([m.coverage for m in pipe.metrics])),
    }


def run(csv_rows: list, tokens: int = 12):
    from benchmarks.bench_sensitivity import trained_model
    cfg, params = trained_model()
    thr = _thresholds(cfg, params)
    device, link = paper_scaled_models(cfg)
    mk = dict(thresholds=thr, device=device, link=link, mode="floe")

    for batch, slots in ((1, 2), (2, 2)):
        hs = _h_stream(cfg, tokens, batch)
        sync = _run(FloEPipeline(params, cfg, cache_slots=slots, **mk), hs)
        rt_pipe = FloEPipeline(params, cfg, cache_slots=slots,
                               use_runtime=True, lookahead=2,
                               residency_policy="weighted", **mk)
        rt = _run(rt_pipe, hs)
        sched = rt_pipe.sched
        tag = f"b={batch}_slots={slots}"
        csv_rows.append((f"prefetch/stall_per_token/sync/{tag}", 0.0,
                         f"{sync['stall_ms']:.3f}ms cov={sync['coverage']:.2f}"))
        csv_rows.append((f"prefetch/stall_per_token/runtime/{tag}", 0.0,
                         f"{rt['stall_ms']:.3f}ms cov={rt['coverage']:.2f}"))
        red = 1.0 - rt["stall_ms"] / max(sync["stall_ms"], 1e-9)
        csv_rows.append((f"prefetch/stall_reduction/{tag}", 0.0,
                         f"{red:.1%} (acceptance: >=30%)"))
        csv_rows.append((f"prefetch/overlap_efficiency/{tag}", 0.0,
                         f"{sched.overlap_efficiency():.2%}"))
        csv_rows.append((
            f"prefetch/precision_recall/{tag}", 0.0,
            f"precision={sched.prefetch_precision():.2f} "
            f"recall={sched.prefetch_recall():.2f}"))

    # residency policies under the same traffic ------------------------------
    hs = _h_stream(cfg, tokens, 2)
    for policy in ("lru", "lfu", "weighted"):
        pipe = FloEPipeline(params, cfg, cache_slots=2, use_runtime=True,
                            lookahead=2, residency_policy=policy, **mk)
        r = _run(pipe, hs)
        csv_rows.append((f"prefetch/policy/{policy}", 0.0,
                         f"stall={r['stall_ms']:.3f}ms tps={r['tps']:.1f}"))

    # batched serving path: union-mask demands shared across the batch -------
    hs = _h_stream(cfg, tokens, 4)
    per_tok = _run(FloEPipeline(params, cfg, cache_slots=2, use_runtime=True,
                                lookahead=2, **mk), hs)
    shared_pipe = FloEPipeline(params, cfg, cache_slots=2, use_runtime=True,
                               lookahead=2, batched_demand=True, **mk)
    shared = _run(shared_pipe, hs)
    csv_rows.append(("prefetch/batched_demand/per_token", 0.0,
                     f"stall={per_tok['stall_ms']:.3f}ms "
                     f"cov={per_tok['coverage']:.2f}"))
    csv_rows.append(("prefetch/batched_demand/union_shared", 0.0,
                     f"stall={shared['stall_ms']:.3f}ms "
                     f"cov={shared['coverage']:.2f} "
                     f"fetches={shared_pipe.sched.stats.demand_fetches}"))
