"""Live re-planning benchmark: drift recovery + migration safety.

The ``repro.replan`` loop watches live routing statistics, re-runs the
planner when the traffic has drifted away from the plan's reference
distribution, and migrates the placement in the background as
``kind="migrate"`` transfers.  Claims pinned here, on the committed
``drift_rotate`` scenario (expert popularity rotating over the run):

* **drift recovery** — after the drift point (median arrival), serving
  with re-planning ON has strictly lower stall/token AND strictly
  higher SLO attainment than the same deployment with re-planning OFF.
  The link is narrowed to 1/16 of the paper-scaled bandwidth and the
  arena budget held at 1.2x the int2 floor so the stale plan actually
  hurts: the rotation moves the hot set off the pinned set, and only
  the re-planner can chase it.
* **decode parity** — migration never pauses or perturbs decode: two
  identical deployments serve the same fixed requests, one with a
  migration executing mid-serve, and emit identical token streams.
  Migrate transfers ride the speculative timeline (demand preempts
  them at chunk granularity), and the serving apply path computes each
  token with exactly its own servable mask (``demand_union``), so a
  staged superset changes nothing.
* **diff idempotence** — ``diff(plan, plan)`` is empty, for both store
  and cluster plans (the delta is a pure function of its inputs).

Micro rows time one drift observation and one plan diff (us_per_call).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.cluster import plan_cluster
from repro.core.offload import LinkModel
from repro.core.pipeline import paper_scaled_models
from repro.deploy import (DeploymentSpec, ModelSpec, ReplanSpec,
                          ResourceSpec, RuntimeSpec, ServingSpec, build)
from repro.replan import DriftDetector, MigrationStep, diff
from repro.store import floor_bytes, plan_store
from repro.workload import ScenarioSpec, generate_requests

SCENARIO = os.path.join(os.path.dirname(__file__), os.pardir, "examples",
                        "scenarios", "drift_rotate.json")
#: re-planner knobs tuned for the scenario's drift rate: a 16-event
#: window reacts within one rotation step, the 4s cooldown and 25%
#: bandwidth share keep migration traffic from displacing demand
REPLAN = ReplanSpec(window=16, threshold=0.15, cooldown_s=4.0,
                    check_every=2, bandwidth_share=0.25)
_CACHE: dict = {}


def _setup():
    if "setup" in _CACHE:
        return _CACHE["setup"]
    probe = DeploymentSpec(model=ModelSpec(arch="mixtral-8x7b", layers=4,
                                           d_model=64, max_experts=8))
    cfg = probe.resolve_config()
    device, link0 = paper_scaled_models(cfg)
    # 1/16 of paper bandwidth: demand fetches of unpinned experts are
    # expensive enough that a stale pinned set dominates stall
    link = LinkModel(peak_bw=link0.peak_bw / 16, launch_us=link0.launch_us,
                     pack_bw=link0.pack_bw / 16)
    vram_gb = 1.2 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    scen = ScenarioSpec.load(SCENARIO)
    _CACHE["setup"] = (cfg, device, link, vram_gb, scen)
    return _CACHE["setup"]


def _spec(vram_gb: float) -> DeploymentSpec:
    return DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=64,
                        max_experts=8),
        resources=ResourceSpec(vram_gb=vram_gb, host_gb=0.05,
                               ladder=("int2",), progressive=False),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=64, policy="slo",
                            online_train=False))


def _serve_arm(replan_on: bool):
    """One serving run over the drift scenario; stats split at the drift
    point (median arrival — the rotation has moved the hot set by then)."""
    cfg, device, link, vram_gb, scen = _setup()
    dep = build(_spec(vram_gb), device=device, link=link)
    ctl = dep.controller
    if replan_on:
        dep._attach_replan(REPLAN)
    reqs = generate_requests(scen, cfg.vocab_size)
    t_drift = float(np.median([r.arrival_t for r in reqs]))
    for r in reqs:
        ctl.submit(r)
    snap = None
    while ctl.step():
        if snap is None and ctl.sched.clock >= t_drift:
            snap = (ctl.pipe.sched.stats.stall_s, ctl.stats["tokens"])
    ctl._retire(ctl.sched.clock)
    stall0, tok0 = snap if snap is not None else (0.0, 0)
    post_stall = (ctl.pipe.sched.stats.stall_s - stall0) \
        / max(ctl.stats["tokens"] - tok0, 1)
    n_post = sum(1 for r in reqs if r.arrival_t >= t_drift)
    attained = sum(1 for r in ctl.completed
                   if r.arrival_t >= t_drift and r.attained)
    return post_stall, attained, n_post, dep


def _decode_parity():
    """Identical serving outputs with a migration executing mid-stream.

    The serving apply path (``demand_union``) guarantees each token
    computes with exactly its own servable mask regardless of what the
    cache happens to hold, so placement churn — which only ever ADDS
    staged channels — cannot perturb the numbers.  (The raw
    ``decode_token`` path reuses stale slices by design, so its outputs
    legitimately depend on cache history; parity is a serving-path
    contract.)"""
    from repro.replan import MigrationDelta, MigrationExecutor
    from repro.serving.controller import SLORequest
    cfg, device, link, vram_gb, _ = _setup()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(6)]
    outs = {}
    for arm in ("off", "on"):
        dep = build(_spec(vram_gb), device=device, link=link)
        ctl = dep.controller
        ex = None
        if arm == "on":
            # migrate the pinned set: unpin everything pinned, pin the
            # complement — the harshest placement churn the diff emits
            pinned = set(dep.plan.pinned)
            moe = [li for li, st in enumerate(ctl.pipe.sched.stores)
                   if st is not None]
            steps = tuple(
                [MigrationStep(op="unpin", key=k) for k in sorted(pinned)]
                + [MigrationStep(op="pin", key=(li, e))
                   for li in moe for e in range(cfg.num_experts)
                   if (li, e) not in pinned][:8])
            ex = MigrationExecutor(ctl.pipe.sched, bandwidth_share=1.0)
            ex.begin(MigrationDelta(steps=steps), ctl.sched.clock)
        for i, p in enumerate(prompts):
            ctl.submit(SLORequest(uid=i, prompt=p, max_new_tokens=12,
                                  slo_ms=1e6))
        while ctl.step():
            if ex is not None:
                ex.poll(ctl.sched.clock)
        ctl._retire(ctl.sched.clock)
        outs[arm] = ({r.uid: list(r.output) for r in ctl.completed},
                     ex.stats.transfers if ex is not None else 0)
    same = outs["off"][0] == outs["on"][0] and len(outs["off"][0]) == 6
    return same, outs["on"][1]


def run(csv_rows: list):
    cfg, device, link, vram_gb, scen = _setup()

    # ---- drift recovery: replan off vs on over drift_rotate --------------
    off_stall, off_att, n_post, _ = _serve_arm(False)
    on_stall, on_att, _, dep = _serve_arm(True)
    rep = dep._replanner.report()
    csv_rows.append((f"replan/post_drift_stall_ms/{scen.name}/off", 0.0,
                     f"{off_stall * 1e3:.3f}"))
    csv_rows.append((f"replan/post_drift_stall_ms/{scen.name}/on", 0.0,
                     f"{on_stall * 1e3:.3f}"))
    csv_rows.append((
        f"replan/loop/{scen.name}", 0.0,
        f"replans={rep['replans']} triggers={rep['drift_triggers']} "
        f"checks={rep['checks']} migrate_transfers={rep['migrate_transfers']} "
        f"migrate_MiB={rep['migrate_bytes'] / 2 ** 20:.2f} "
        f"pins={rep['migrate_pins']} unpins={rep['migrate_unpins']}"))
    recovered = on_stall < off_stall and on_att > off_att
    csv_rows.append((
        "replan/drift_recovery", 0.0,
        f"{recovered} (stall/token {off_stall * 1e3:.3f} -> "
        f"{on_stall * 1e3:.3f}ms; post-drift attained {off_att}/{n_post} -> "
        f"{on_att}/{n_post}; acceptance: replan-on strictly lower stall "
        f"AND strictly higher attainment)"))

    # ---- decode parity: migration never pauses or perturbs decode --------
    same, n_migr = _decode_parity()
    csv_rows.append((
        "replan/decode_parity", 0.0,
        f"{same} (6 served requests emit identical token streams with "
        f"{n_migr} migrate transfers executing mid-serve vs none)"))

    # ---- diff idempotence + micro timings --------------------------------
    rng = np.random.default_rng(0)
    ref = rng.random((cfg.num_layers, cfg.num_experts))
    ref /= ref.sum(axis=1, keepdims=True)
    rot = np.roll(ref, 3, axis=1)
    sp = plan_store(cfg, ref, vram_gb=vram_gb, host_gb=0.05,
                    ladder=("int2",), progressive=False)
    sp2 = plan_store(cfg, rot, vram_gb=vram_gb, host_gb=0.05,
                     ladder=("int2",), progressive=False)
    cp = plan_cluster(cfg, ref, n_devices=2, vram_gb_per_device=vram_gb,
                      host_gb=0.05, ladder=("int2",))
    idem = diff(sp, sp).empty and diff(cp, cp).empty
    csv_rows.append((
        "replan/diff_idempotent", 0.0,
        f"{idem} (diff(plan, plan).empty for StorePlan and ClusterPlan)"))

    n = 200
    t0 = time.perf_counter()
    for _ in range(n):
        diff(sp, sp2)
    diff_us = (time.perf_counter() - t0) / n * 1e6
    delta = diff(sp, sp2)
    csv_rows.append(("replan/diff_us_per_call", diff_us,
                     f"steps={len(delta)} [{delta.summary()}]"))

    det = DriftDetector(ref, window=16, threshold=0.15)
    freqs = {(li, e): int(rng.integers(1, 50))
             for li in range(cfg.num_layers) for e in range(cfg.num_experts)}
    t0 = time.perf_counter()
    for _ in range(n):
        det.observe(freqs, 0.0)
    obs_us = (time.perf_counter() - t0) / n * 1e6
    csv_rows.append(("replan/drift_observe_us_per_call", obs_us,
                     f"readings={det.readings}"))
