"""Paper Fig. 7: compact-layout transfer latency / bandwidth utilization vs
chunk size.

Two measurements: (a) REAL host memcpy bandwidth of gathering masked expert
records under the compact vs naive (scattered gate-column + down-row)
layouts — the packing step the paper accelerates with SIMD; (b) the modeled
end-to-end link time per chunk size from the LinkModel (Fig. 7's curve).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.offload import LinkModel

D, F = 4096, 14336  # Mixtral expert
KEEP = 0.2
CHUNKS = (1, 5, 20, 50, 200, 1000)


def _gather_compact(records, idx, chunk):
    outs = []
    for s in range(0, len(idx), chunk):
        outs.append(records[idx[s:s + chunk]])  # one contiguous-ish gather
    return np.concatenate(outs, 0)


def _gather_naive(gate, down, idx, chunk):
    outs = []
    for s in range(0, len(idx), chunk):
        sel = idx[s:s + chunk]
        outs.append(np.ascontiguousarray(gate[:, sel]).T)  # strided columns
        outs.append(down[sel])
    return np.concatenate(outs, 0)


def run(csv_rows: list, trials: int = 3):
    rng = np.random.default_rng(0)
    gate = rng.standard_normal((D, F), np.float32).astype(np.float16)
    down = rng.standard_normal((F, D), np.float32).astype(np.float16)
    records = np.ascontiguousarray(
        np.concatenate([gate.T, down], axis=1))  # (F, 2D) compact
    idx = np.sort(rng.choice(F, int(F * KEEP), replace=False))
    total_bytes = len(idx) * 2 * D * 2
    link = LinkModel()

    for chunk in CHUNKS:
        # real host packing bandwidth
        for fn, name in ((_gather_compact, "compact"),):
            fn(records, idx, chunk)  # warm
            t0 = time.perf_counter()
            for _ in range(trials):
                fn(records, idx, chunk)
            dt = (time.perf_counter() - t0) / trials
            bw = total_bytes / dt / 1e9
            csv_rows.append((f"fig7/pack_{name}/chunk={chunk}", dt * 1e6,
                             f"host_pack_bw={bw:.2f}GB/s"))
        # modeled end-to-end PCIe time (the paper's y-axis)
        n_chunks = max(1, len(idx) // chunk)
        t_model = link.transfer_time(total_bytes, n_chunks)
        util = total_bytes / t_model / link.peak_bw
        csv_rows.append((f"fig7/link_model/chunk={chunk}", t_model * 1e6,
                         f"pcie_util={util:.2%}"))

    # naive layout comparison at the paper's optimal chunk (50)
    for fn, name in ((_gather_compact, "compact"), (_gather_naive, "naive")):
        args = (records, idx, 50) if name == "compact" else \
            (gate, down, idx, 50)
        fn(*args)
        t0 = time.perf_counter()
        for _ in range(trials):
            fn(*args)
        dt = (time.perf_counter() - t0) / trials
        csv_rows.append((f"fig7/layout_{name}@chunk50", dt * 1e6,
                         f"bw={total_bytes / dt / 1e9:.2f}GB/s"))
