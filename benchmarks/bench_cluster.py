"""Multi-GPU cluster benchmark: stall/token and link utilization vs
device count at FIXED per-device VRAM, plus a replication-factor sweep.

FloE's premise is one memory-constrained GPU behind one PCIe link; the
cluster subsystem adds devices, each with its own link and residency
arena.  Two curves:

* **scaling (fixed residency config)** — per-device residency is held at
  the planner floor (``max_slots=1``, no pins) so the sweep isolates
  what devices inherently add: parallel host→device links (a layer's
  union of demands splits across owners) and aggregate arena slots.
  Stall/token must STRICTLY decrease 1→2→4 devices (the acceptance
  bar; the conformance test pins 1→2).
* **planner spend** — the same budgets with the greedy spend unleashed:
  splitting experts across devices frees per-device headroom that the
  planner converts into pinned experts and slots, so stall collapses
  even faster (at this reduced scale it typically reaches zero).

The link is a deliberately narrow PCIe-3-class model (¼ of the
paper-scaled bandwidth, same compute model for every device count) so
transfer time dominates and the device-count effect is visible at toy
scale; the replication sweep routes the hottest experts' fetches to the
least-loaded replica link.
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import plan_cluster
from repro.common.config import reduced
from repro.configs import get_config
from repro.core import sparsify
from repro.core.offload import LinkModel
from repro.core.pipeline import (FloEPipeline, _unstack_layers,
                                 paper_scaled_models)
from repro.models import transformer as tf
from repro.store import floor_bytes, measure_frequencies

DEVICES = (1, 2, 4)
REPLICATES = (0, 1, 2)
TOKENS = 6
BATCH = 8
ALPHA = 0.6
_CACHE: dict = {}


def _setup():
    """An 8-expert reduced Mixtral (more experts than any device's
    residency floor can hold) + a narrow PCIe-3-class link."""
    if "setup" in _CACHE:
        return _CACHE["setup"]
    cfg = reduced(get_config("mixtral_8x7b"), layers=4, d_model=128,
                  max_experts=8)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (64, cfg.d_model))
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    device, link0 = paper_scaled_models(cfg)
    link = LinkModel(peak_bw=link0.peak_bw / 4, launch_us=link0.launch_us,
                     pack_bw=link0.pack_bw / 4)
    freqs = measure_frequencies(layers, cfg)
    vram_gb = 1.05 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    _CACHE["setup"] = (cfg, params, thr, device, link, freqs, vram_gb)
    return _CACHE["setup"]


def _h_stream(cfg, steps: int, batch: int, alpha: float):
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (batch, cfg.d_model), jnp.float32)
    out = [h]
    for _ in range(steps - 1):
        key, sub = jax.random.split(key)
        n = jax.random.normal(sub, (batch, cfg.d_model), jnp.float32)
        h = alpha * h + (1.0 - alpha ** 2) ** 0.5 * n
        out.append(h)
    return out


def _decode(n_devices: int, *, replicate: int = 0,
            fixed_config: bool = True):
    cfg, params, thr, device, link, freqs, vram_gb = _setup()
    knobs = (dict(max_pinned_per_device=0, max_slots=1)
             if fixed_config else {})
    plan = plan_cluster(cfg, freqs, n_devices=n_devices,
                        vram_gb_per_device=vram_gb, host_gb=0.0005,
                        ladder=("int2",), replicate=replicate, **knobs)
    pipe = FloEPipeline(params, cfg, thresholds=thr, device=device,
                        link=link, mode="floe", use_runtime=True,
                        cluster_plan=plan,
                        store_dir=tempfile.mkdtemp(prefix="bench-clu-"),
                        store_freqs=freqs)
    for h in _h_stream(cfg, TOKENS, BATCH, ALPHA):
        pipe.decode_token(h)
    for pool in pipe.device_pools:
        pool.check_invariants()
    stall = sum(m.stall_s for m in pipe.metrics) / TOKENS
    util = pipe.engine.aggregate_utilization(pipe.sched.clock)
    return pipe, plan, stall, util


def run(csv_rows: list):
    # ---- curve A: device scaling at a fixed residency configuration ------
    curve = []
    for n in DEVICES:
        pipe, plan, stall, util = _decode(n)
        curve.append(stall)
        s = pipe.sched.stats
        busy = pipe.engine.summary()["busy_s_per_device"]
        csv_rows.append((
            f"cluster/stall_per_token_ms/devices={n}", 0.0,
            f"{stall * 1e3:.3f}"))
        csv_rows.append((
            f"cluster/scaling/devices={n}", 0.0,
            f"stall/token={stall * 1e3:.3f}ms agg_link_util={util:.2%} "
            f"fetches={s.demand_fetches} "
            f"busy/dev={[round(b * 1e3) for b in busy]}ms "
            f"[{plan.summary()}]"))
    strictly = all(curve[i] > curve[i + 1] for i in range(len(curve) - 1))
    csv_rows.append(("cluster/stall_strictly_decreasing", 0.0,
                     f"{strictly} ({' -> '.join(f'{s * 1e3:.3f}ms' for s in curve)}"
                     f" over devices={DEVICES})"))

    # ---- curve B: the same budgets with the planner spend unleashed ------
    for n in DEVICES:
        pipe, plan, stall, util = _decode(n, fixed_config=False)
        pins = [len(p) for p in plan.pinned_per_device]
        csv_rows.append((
            f"cluster/planner_spend/devices={n}", 0.0,
            f"stall/token={stall * 1e3:.3f}ms agg_link_util={util:.2%} "
            f"pins/dev={pins} slots/layer={plan.slots_per_layer} "
            f"(headroom from splitting experts -> pins+slots)"))

    # ---- replication-factor sweep at the largest device count ------------
    n = DEVICES[-1]
    for rep in REPLICATES:
        pipe, plan, stall, util = _decode(n, replicate=rep)
        sel = pipe.sched.selector
        csv_rows.append((
            f"cluster/replication/devices={n}/replicate={rep}", 0.0,
            f"stall/token={stall * 1e3:.3f}ms agg_link_util={util:.2%} "
            f"replica_routed={sel.replica_choices} "
            f"routed/dev={[sel.routed[d] for d in range(n)]}"))

    # ---- scenario-driven serving cell: live drift on a 2-device fleet ----
    # the committed drift_rotate scenario served through a 2-device
    # deployment with live re-planning ON: the rotation pulls the hot
    # set off both devices' pinned sets and the re-planner chases it
    # with cluster-plan migrations (pin/unpin and cross-device re-homes
    # as background transfers) — the cluster-path replan loop under a
    # real arrival process, not a synthetic h-stream
    import dataclasses as _dc
    import os
    from repro.deploy import (DeploymentSpec, ModelSpec, ReplanSpec,
                              ResourceSpec, RuntimeSpec, ServingSpec, build)
    from repro.workload import ScenarioSpec
    cfg, params, thr, device, link, freqs, vram_gb = _setup()
    scen = _dc.replace(ScenarioSpec.load(os.path.join(
        os.path.dirname(__file__), os.pardir, "examples", "scenarios",
        "drift_rotate.json")), n_requests=12)
    spec = DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=128,
                        max_experts=8),
        resources=ResourceSpec(vram_gb=vram_gb, host_gb=0.05, devices=2,
                               ladder=("int2",), progressive=False),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=64, policy="slo",
                            online_train=False))
    dep = build(spec, device=device, link=link)
    dep.serve(scenario=scen,
              replan=ReplanSpec(window=16, threshold=0.15, cooldown_s=4.0,
                                check_every=2, bandwidth_share=0.25))
    crep = dep.controller.report()
    rrep = dep._replanner.report()
    csv_rows.append((
        f"cluster/scenario/{scen.name}/devices=2", 0.0,
        f"slo={crep['slo_attainment']:.0%} tps={crep['tokens_per_s']:.1f} "
        f"rej={crep['rejected']} replans={rrep['replans']} "
        f"migrate_transfers={rrep['migrate_transfers']} "
        f"rehomes={rrep['migrate_rehomes']} pins={rrep['migrate_pins']} "
        f"(acceptance: scenario completes with the cluster replan loop "
        f"live)"))
