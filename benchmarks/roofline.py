"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) on the single-pod 16×16 mesh:

  compute term    = flops_per_device / peak_FLOP/s          (197e12 bf16)
  memory term     = hbm_bytes_per_device / HBM_bw           (819e9 B/s)
  collective term = collective_bytes_per_device / link_bw   (~50e9 B/s/link)

flops/hbm/collective come from the trip-weighted HLO analysis of the
compiled partition (see launch/hlo_analysis.py).  MODEL_FLOPS is the
analytic 6·N_active·T (train) or 2·N_active·T (inference) per device.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.common.config import SHAPES
from repro.configs import get_config

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / devices
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / devices
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n * tokens / devices


def load(arch: str, shape: str, mesh: str = "16x16") -> dict | None:
    p = RESULTS / f"{arch}.{shape}.{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def terms(rec: dict) -> dict:
    f = rec.get("flops_per_device", 0.0)
    b = rec.get("hbm_bytes_per_device", 0.0)
    c = rec.get("collective_bytes_per_device", 0.0)
    t_c = f / PEAK_FLOPS
    t_m = b / HBM_BW
    t_x = c / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops_per_device(rec["arch"], rec["shape"],
                                rec.get("devices", 256))
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / f if f else 0.0,
        "bound_step_s": max(t_c, t_m, t_x),
    }


FIX_HINTS = {
    "compute": "reduce replicated compute (shard attention projections / "
               "drop remat recompute of cheap ops)",
    "memory": "fuse softmax/score traffic (flash-attention kernel), bf16 "
              "scores, smaller microbatch working set",
    "collective": "drop FSDP gathers where weights fit (1D sharding), "
                  "overlap psum with compute, reduce f32 gradient reduction "
                  "to bf16",
}


def table(mesh: str = "16x16") -> list[dict]:
    rows = []
    for p in sorted(RESULTS.glob(f"*.{mesh}.json")):
        rec = json.loads(p.read_text())
        t = terms(rec)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            **{k: t[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "dominant", "useful_flops_ratio",
                                 "bound_step_s")},
            "temp_gb": rec.get("temp_bytes_per_device", 0) / 2**30,
            "args_gb": rec.get("argument_bytes_per_device", 0) / 2**30,
            "hint": FIX_HINTS[t["dominant"]],
        })
    return rows


def run(csv_rows: list):
    for r in table():
        csv_rows.append((
            f"roofline/{r['arch']}.{r['shape']}",
            r["bound_step_s"] * 1e6,
            f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
            f"collective={r['collective_s']:.3e}s dominant={r['dominant']} "
            f"useful_flops={r['useful_flops_ratio']:.2f} "
            f"mem/device={r['args_gb'] + r['temp_gb']:.1f}GiB"))


def main():
    rows = table()
    if not rows:
        print("no dry-run results yet — run repro.launch.sweep first")
        return
    hdr = (f"{'arch':<18} {'shape':<12} {'compute':>10} {'memory':>10} "
           f"{'collect':>10} {'dominant':>10} {'useful':>7} {'mem GiB':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:<18} {r['shape']:<12} {r['compute_s']:>10.3e} "
              f"{r['memory_s']:>10.3e} {r['collective_s']:>10.3e} "
              f"{r['dominant']:>10} {r['useful_flops_ratio']:>7.2f} "
              f"{r['args_gb'] + r['temp_gb']:>8.1f}")


if __name__ == "__main__":
    main()
