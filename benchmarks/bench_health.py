"""Live health layer benchmark: burn-rate alerting + incident forensics.

``repro.obs.health`` watches the live event stream — per-tenant SLO
attainment through multi-window burn-rate alerting, stall-cause
composition and link health through anomaly detectors — and freezes a
byte-deterministic incident bundle when an alert fires.  Claims pinned
here, on the committed scenarios:

* **alert before collapse** — on ``flash_crowd`` (an 8x arrival burst
  at t=20s), the first burn-rate alert fires STRICTLY BEFORE the
  trailing-window SLO attainment reaches its minimum: the burn windows
  see the error budget burning while most of the damage is still
  queued, which is the entire point of multi-window burn alerting over
  raw attainment dashboards.  ``detection_latency_s`` records first
  alert time minus burst onset.
* **zero false positives** — the stationary ``diurnal_mix`` run (no
  burst, no drift) fires ZERO alerts under the same health spec: the
  windows that page within seconds of the burst never cross threshold
  on load the deployment actually sustains.
* **bundle determinism** — two identical flash_crowd runs freeze
  byte-identical incident bundles (Perfetto slice, metrics snapshot,
  stall attribution, request waterfalls and the replayable scenario
  slice are all rendered with sorted keys off the simulated clock).
  Bundles land in ``bench-incidents/`` so CI ships them as artifacts
  on a failed run.
* **zero overhead** — the monitor is a pure bus consumer: serving with
  health ON emits identical decode outputs and an identical event
  stream (minus its own ``health.*`` events) as serving with health
  OFF.

Calibration: the reduced deployment (link at 1/2 paper bandwidth,
2 slots, 1.2x int2 arena) sustains ~0.8 req/s.  flash_crowd's baseline
rate is exactly that — sustainable until the 8x burst — and is served
with ``n_requests=48`` so the burst has body (the bench_memory
``dataclasses.replace`` idiom; the committed file keeps its 24).
diurnal_mix's committed rate (1.5/s peak 2.4/s) exceeds the reduced
deployment's capacity outright, so its arrival rate is scaled to 0.2/s
— same tenants, same diurnal modulation, same seed — putting its peak
in the sustainable regime a provisioned deployment would actually run
at.  The anomaly threshold sits above the cold-start composition
transient (the arena filling up is eviction-heavy on EVERY fresh
deployment; TV peaks ~0.6 on diurnal_mix) because a burst that merely
scales every stall cause up is by design not a composition flip.

Micro row times one ``HealthMonitor.on_event`` fold (us_per_call).
"""
from __future__ import annotations

import dataclasses
import os
import time

from repro import obs
from repro.core.offload import LinkModel
from repro.core.pipeline import paper_scaled_models
from repro.deploy import (DeploymentSpec, HealthSpec, ModelSpec,
                          ResourceSpec, RuntimeSpec, ServingSpec, build)
from repro.store import floor_bytes
from repro.workload import ScenarioSpec

_SCEN_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "examples",
                         "scenarios")
#: where flash_crowd incident bundles land (CI uploads this directory
#: as the ``incident-bundles`` artifact when the gate fails)
INCIDENT_DIR = "bench-incidents"
#: burn windows sized to the flash_crowd burst (10s at 8x): the fast
#: window reacts within a few finishes, the slow window spans the whole
#: burst; min_events=8 rides out cold-start misses; queue-delay link
#: alerting is OFF because the narrowed link legitimately queues
#: transfers; anomaly threshold above the cold-start transient (see
#: module docstring)
HEALTH = HealthSpec(slo_target=0.9, fast_window_s=5.0, slow_window_s=30.0,
                    page_burn=4.0, ticket_burn=2.0, min_events=8,
                    anomaly_window=16, anomaly_threshold=0.65,
                    link_util_threshold=3.0, queue_delay_s=0.0,
                    cooldown_s=10.0, max_incidents=4)
#: trailing window for the independent attainment timeline the alert
#: must beat (seconds of finish/reject outcomes)
COLLAPSE_WINDOW_S = 15.0
_CACHE: dict = {}


def _setup():
    if "setup" in _CACHE:
        return _CACHE["setup"]
    probe = DeploymentSpec(model=ModelSpec(arch="mixtral-8x7b", layers=4,
                                           d_model=64, max_experts=8))
    cfg = probe.resolve_config()
    device, link0 = paper_scaled_models(cfg)
    # 1/2 of paper bandwidth: baseline flash_crowd load is sustained,
    # the 8x burst genuinely overwhelms serving
    link = LinkModel(peak_bw=link0.peak_bw / 2, launch_us=link0.launch_us,
                     pack_bw=link0.pack_bw / 2)
    vram_gb = 1.2 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    flash = dataclasses.replace(
        ScenarioSpec.load(os.path.join(_SCEN_DIR, "flash_crowd.json")),
        n_requests=48)
    d0 = ScenarioSpec.load(os.path.join(_SCEN_DIR, "diurnal_mix.json"))
    diurnal = dataclasses.replace(
        d0, n_requests=36, arrival=dataclasses.replace(d0.arrival, rate=0.2))
    _CACHE["setup"] = (cfg, device, link, vram_gb, flash, diurnal)
    return _CACHE["setup"]


def _spec(vram_gb: float) -> DeploymentSpec:
    return DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=64,
                        max_experts=8),
        resources=ResourceSpec(vram_gb=vram_gb, host_gb=0.05,
                               ladder=("int2",), progressive=False),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=64, policy="slo",
                            online_train=False))


class _Timeline:
    """Finish/reject outcome stream — the alert-independent ground truth
    the 'alert before collapse' pin compares against."""

    def __init__(self):
        self.events = []  # (t, attained)

    def on_event(self, ev) -> None:
        if ev.name == "request.finish":
            a = ev.args or {}
            self.events.append((ev.t, bool(a.get("attained", True))))
        elif ev.name == "request.reject":
            self.events.append((ev.t, False))


def _serve(scenario, health, incident_dir: str | None = None):
    """One fresh deployment served over ``scenario``; returns
    (deployment, completed requests, outcome timeline)."""
    cfg, device, link, vram_gb, _, _ = _setup()
    dep = build(_spec(vram_gb), device=device, link=link)
    hl = health
    if hl is not None and incident_dir is not None:
        hl = dataclasses.replace(hl, incident_dir=incident_dir)
    tl = _Timeline()
    with obs.consumer(tl):
        dep.serve(scenario=scenario,
                  health=hl if hl is not None else False)
    return dep, list(dep.controller.completed), tl


def _attainment_min_t(timeline: _Timeline,
                      window_s: float = COLLAPSE_WINDOW_S):
    """(t_min, att_min): when the trailing-window SLO attainment (over
    the finish/reject outcomes of the last ``window_s`` seconds) FIRST
    reaches its minimum — 'collapse' for the acceptance pin."""
    evs = sorted(timeline.events)
    t_min, att_min = None, 2.0
    for t, _ in evs:
        win = [ok for (tt, ok) in evs if t - window_s < tt <= t]
        att = sum(win) / len(win)
        if att < att_min - 1e-12:
            att_min, t_min = att, t
    return t_min, att_min


def _zero_overhead():
    """Health ON must not perturb serving: identical decode outputs and
    an identical event stream once the monitor's own ``health.*``
    events are filtered out."""
    _, _, _, _, flash, _ = _setup()
    outs, streams = {}, {}
    for arm in ("off", "on"):
        tracer = obs.Tracer()
        with obs.consumer(tracer):
            dep, completed, _ = _serve(flash,
                                       HEALTH if arm == "on" else None)
        outs[arm] = {r.uid: list(r.output) for r in completed}
        streams[arm] = [(e.name, e.t, e.dur, e.device, e.lane, e.model,
                         e.args) for e in tracer.events if e.cat != "health"]
    same_out = outs["off"] == outs["on"] and len(outs["off"]) > 0
    same_stream = streams["off"] == streams["on"]
    return same_out, same_stream, len(streams["off"])


def run(csv_rows: list):
    _, _, _, _, flash, diurnal = _setup()

    # ---- flash_crowd: burn alert before attainment bottoms out -----------
    dep, _, tl = _serve(flash, HEALTH, incident_dir=INCIDENT_DIR)
    mon = dep._health
    burn = [a for a in mon.alerts if a.signal in ("attainment", "tpot")]
    alert_t = burn[0].t if burn else None
    t_min, att_min = _attainment_min_t(tl)
    before = (alert_t is not None and t_min is not None
              and alert_t < t_min)
    rep = mon.report()
    a_t = alert_t if alert_t is not None else -1.0
    m_t = t_min if t_min is not None else -1.0
    csv_rows.append((
        "health/alert_before_collapse/flash_crowd", 0.0,
        f"{before} (first burn alert t={a_t:.2f}s, trailing-"
        f"{COLLAPSE_WINDOW_S:.0f}s attainment bottoms out at {att_min:.2f} "
        f"at t={m_t:.2f}s; acceptance: alert strictly earlier)"))
    csv_rows.append((
        "health/loop/flash_crowd", 0.0,
        f"alerts={rep['alerts']} pages={rep['pages']} "
        f"tickets={rep['tickets']} anomalies={rep['anomalies']} "
        f"incidents={len(rep['incidents'])} events={rep['events']}"))
    burst_t = flash.arrival.bursts[0].start_t
    latency = (alert_t - burst_t) if alert_t is not None else -1.0
    csv_rows.append(("health/detection_latency_s", 0.0, f"{latency:.3f}"))

    # ---- diurnal_mix: stationary load stays alert-free -------------------
    dep_d, completed_d, _ = _serve(diurnal, HEALTH)
    rep_d = dep_d._health.report()
    quiet = rep_d["alerts"] == 0
    csv_rows.append((
        "health/false_positives/diurnal_mix", 0.0,
        f"{quiet} (alerts={rep_d['alerts']} over {rep_d['events']} events, "
        f"{len(completed_d)} completions; acceptance: stationary run "
        f"fires zero alerts)"))

    # ---- bundle determinism + size ---------------------------------------
    dep2, _, _ = _serve(flash, HEALTH)
    b1, b2 = mon.bundles, dep2._health.bundles
    deterministic = len(b1) > 0 and b1 == b2
    csv_rows.append((
        "health/bundle_deterministic", 0.0,
        f"{deterministic} (bundles={len(b1)} byte-identical across two "
        f"identical flash_crowd runs)"))
    size_kb = (sum(len(b) for b in b1) / len(b1) / 1024.0) if b1 else 0.0
    csv_rows.append(("health/bundle_size_kb", 0.0, f"{size_kb:.2f}"))

    # ---- zero overhead ---------------------------------------------------
    same_out, same_stream, n_ev = _zero_overhead()
    csv_rows.append((
        "health/zero_overhead", 0.0,
        f"{same_out and same_stream} (decode outputs identical="
        f"{same_out}, {n_ev}-event stream identical={same_stream} with "
        f"the monitor attached vs detached)"))

    # ---- micro: one monitor fold -----------------------------------------
    from repro.obs.events import Event
    from repro.obs.health import HealthMonitor
    m = HealthMonitor(HEALTH)
    ev = Event(seq=0, t=1.0, name="request.finish", cat="serving", dur=0.0,
               device=0, model="", lane=None,
               args={"uid": 0, "tenant": "chat", "attained": True,
                     "tpot_s": 0.01})
    n, reps = 1000, 5
    fold_us = float("inf")
    for _ in range(reps):  # best-of-reps: the micro row gates CI at 10%
        t0 = time.perf_counter()
        for _ in range(n):
            m.on_event(ev)
        fold_us = min(fold_us, (time.perf_counter() - t0) / n * 1e6)
    csv_rows.append(("health/on_event_us_per_call", fold_us,
                     f"events={m.events_seen}"))
