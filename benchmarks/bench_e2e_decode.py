"""Paper Fig. 6 / Fig. 8: end-to-end decode TPS — FloE vs naive offloading
vs fully-resident, and TPS vs cache budget (VRAM proxy).

Latency is MODELED with paper-ratio constants (repro.core.pipeline.
paper_scaled_models) on a trained small MoE; real jax compute still runs so
outputs are checked for fidelity alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsify
from repro.core.pipeline import FloEPipeline, _unstack_layers, \
    paper_scaled_models


def _thresholds(cfg, params):
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (128, cfg.d_model)) * 0.5
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    return thr


def run(csv_rows: list, tokens: int = 6):
    from benchmarks.bench_sensitivity import trained_model
    cfg, params = trained_model()
    thr = _thresholds(cfg, params)
    device, link = paper_scaled_models(cfg)

    results = {}
    for mode in ("resident", "naive", "floe"):
        pipe = FloEPipeline(params, cfg, thresholds=thr, cache_slots=4,
                            mode=mode, device=device, link=link)
        outs = []
        for i in range(tokens):
            h = jax.random.normal(jax.random.PRNGKey(100 + i),
                                  (1, cfg.d_model), jnp.float32) * 0.3
            out, m = pipe.decode_token(h)
            outs.append(out)
        results[mode] = (pipe, outs)
        csv_rows.append((f"fig6/tps/{mode}", 0.0,
                         f"tps={pipe.tokens_per_second():.1f}"))

    tps = {m: p.tokens_per_second() for m, (p, _) in results.items()}
    csv_rows.append(("fig6/speedup_floe_vs_naive", 0.0,
                     f"{tps['floe'] / tps['naive']:.2f}x (paper: 48.7x vs "
                     "DeepSpeed-MII, 2.6x vs Mixtral-Offloading)"))
    csv_rows.append(("fig6/floe_fraction_of_resident", 0.0,
                     f"{tps['floe'] / tps['resident']:.2%} (paper: 91%)"))
    err = float(np.mean([
        float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-9))
        for a, b in zip(results["floe"][1], results["resident"][1])]))
    csv_rows.append(("fig6/floe_output_rel_err", 0.0, f"{err:.4f}"))

    # ---- Fig 6 inset: TPS vs output length (cold-cache amortization) -----
    # paper: "with longer outputs ... TPS improves as layer-wise expert
    # replacement overhead is amortized over longer sequences."
    for n_out in (2, 8, 24):
        pipe = FloEPipeline(params, cfg, thresholds=thr, cache_slots=4,
                            mode="floe", device=device, link=link)
        for i in range(n_out):
            h = jax.random.normal(jax.random.PRNGKey(300 + i),
                                  (1, cfg.d_model), jnp.float32) * 0.3
            pipe.decode_token(h)
        csv_rows.append((f"fig6/tps_vs_output_len/{n_out}", 0.0,
                         f"tps={pipe.tokens_per_second():.1f}"))

    # ---- Fig 8: TPS vs cache budget (slots per layer ~ VRAM) -------------
    for slots in (1, 2, 4, 8):
        pipe = FloEPipeline(params, cfg, thresholds=thr, cache_slots=slots,
                            mode="floe", device=device, link=link)
        for i in range(tokens):
            h = jax.random.normal(jax.random.PRNGKey(200 + i),
                                  (1, cfg.d_model), jnp.float32) * 0.3
            pipe.decode_token(h)
        csv_rows.append((f"fig8/tps_vs_cache/slots={slots}", 0.0,
                         f"tps={pipe.tokens_per_second():.1f} "
                         f"hit_rate={_hit_rate(pipe):.2f}"))


def _hit_rate(pipe):
    hits = sum(m.expert_hits for m in pipe.metrics)
    miss = sum(m.expert_misses for m in pipe.metrics)
    return hits / max(hits + miss, 1)
