"""Memory-hierarchy benchmark: footprint vs stall/token across VRAM budgets.

The paper's headline is a MEMORY result (8.5× footprint reduction, Mixtral
on 11 GB); this suite makes the knob continuous: sweep the planner over a
range of VRAM budgets (fractions of the dense-resident footprint) and
measure the modeled stall/token the tiered store pays at each point — the
footprint↔latency tradeoff curve.  A second experiment isolates
progressive precision: the same plan decoded with draft-then-refine demand
fetches vs single-shot full-format fetches (demand stall must drop).

Also reports the analytic footprint of the real Mixtral-8x7B config across
paper-relevant budgets, planner-solved (is 11 GB feasible? what formats?).
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import FloEPipeline, _unstack_layers, \
    paper_scaled_models
from repro.store import (dense_residency_bytes, floor_bytes,
                         measure_frequencies, plan_store)

#: budget sweep for the quality curve, fractions of dense-resident
FRACS = (0.5, 0.62, 0.75, 0.9)
#: budget sweep for the stall curve, multiples of the leanest footprint
#: (samples the pin/slot growth region before it saturates)
FLOOR_MULTS = (1.001, 1.2, 1.45, 1.8)
TOKENS = 6


def _decode(cfg, params, thr, freqs, plan, device, link, *,
            tokens: int = TOKENS):
    pipe = FloEPipeline(params, cfg, thresholds=thr, use_runtime=True,
                        store_plan=plan,
                        store_dir=tempfile.mkdtemp(prefix="bench-mem-"),
                        store_freqs=freqs, device=device, link=link)
    for i in range(tokens):
        h = jax.random.normal(jax.random.PRNGKey(100 + i),
                              (1, cfg.d_model), jnp.float32) * 0.3
        pipe.decode_token(h)
    pipe.device_pool.check_invariants()
    stall = sum(m.stall_s for m in pipe.metrics) / tokens
    cov = float(np.mean([m.coverage for m in pipe.metrics]))
    return pipe, stall, cov


def _servable_fraction(cfg, layers, thr, freqs, plan) -> float:
    """Activation-weighted mean over experts of |true mask ∩ kept| /
    |true mask| on calibration states: the fraction of needed channels
    the planned formats can EVER stage."""
    from repro.store import formats as F
    xcal = jax.random.normal(jax.random.PRNGKey(7),
                             (32, cfg.d_model)) * 0.5
    num, den = 0.0, 0.0
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            fmt = plan.format_for(li, e)
            u = np.asarray(xcal @ layer["moe"]["we_up"][e])
            mask = np.abs(u) >= thr[li, e]
            rank = F.rank_channels_by_upnorm(layer["moe"]["we_up"][e])
            kept = np.zeros(mask.shape[1], bool)
            kept[rank[:F.kept_channels(cfg.moe_d_ff, fmt.keep_ratio)]] = True
            need = mask.sum()
            w = float(freqs[li, e])
            if need:
                num += w * float((mask & kept[None, :]).sum()) / float(need)
                den += w
    return num / max(den, 1e-9)


def run(csv_rows: list):
    from benchmarks.bench_e2e_decode import _thresholds
    from benchmarks.bench_sensitivity import trained_model
    cfg, params = trained_model()
    thr = _thresholds(cfg, params)
    device, link = paper_scaled_models(cfg)
    layers = _unstack_layers(params, cfg)
    freqs = measure_frequencies(layers, cfg)
    dense = dense_residency_bytes(cfg)

    # ---- curve A: footprint vs stall/token (quality held constant) -------
    # every expert stays in the leanest format so per-fetch bytes are
    # fixed; the budget buys pinned experts + residency slots — the pure
    # memory↔stall tradeoff.  Budgets sample the growth region just above
    # the leanest feasible footprint.
    floor = floor_bytes(cfg, ("int2",))
    curve = []
    for mult in FLOOR_MULTS:
        plan = plan_store(cfg, freqs, vram_gb=mult * floor / 2 ** 30,
                          host_gb=0.05, ladder=("int2",))
        pipe, stall, cov = _decode(cfg, params, thr, freqs, plan, device,
                                   link)
        fp = plan.footprint_bytes()
        curve.append((fp, stall))
        csv_rows.append((
            f"memory/footprint_vs_stall/vram={mult:.2f}x_floor", 0.0,
            f"footprint={fp / 2 ** 20:.2f}MiB stall/token="
            f"{stall * 1e3:.3f}ms coverage={cov:.2f} "
            f"[{plan.summary()}]"))
    mono = all(curve[i][0] <= curve[i + 1][0] and
               curve[i][1] >= curve[i + 1][1] * 0.999
               for i in range(len(curve) - 1))
    csv_rows.append(("memory/tradeoff_monotone", 0.0,
                     f"{mono} (footprint up => stall/token down, "
                     f"{len(curve)} budgets)"))

    # ---- curve B: footprint vs servable coverage (the quality knob) ------
    # the full ladder: spare budget upgrades cold experts int2→int4→fp16,
    # buying mask coverage (output fidelity) with footprint.  Servable
    # fraction — how much of the true contextual mask the formats can ever
    # stage — is the knob's direct, deterministic readout (kept sets nest
    # across the ladder, so it is monotone when the planner behaves).
    qcurve = []
    for frac in FRACS:
        plan = plan_store(cfg, freqs, vram_gb=frac * dense / 2 ** 30,
                          host_gb=0.05, max_pinned=0)
        serv = _servable_fraction(cfg, layers, thr, freqs, plan)
        qcurve.append((plan.footprint_bytes(), serv))
        csv_rows.append((
            f"memory/footprint_vs_servable/vram={frac:.2f}x_dense", 0.0,
            f"footprint={plan.footprint_bytes() / 2 ** 20:.2f}MiB "
            f"servable={serv:.3f} [{plan.summary()}]"))
    qmono = all(qcurve[i][1] <= qcurve[i + 1][1] + 1e-9
                for i in range(len(qcurve) - 1))
    csv_rows.append(("memory/quality_knob_monotone", 0.0,
                     f"{qmono} (footprint up => servable coverage up)"))

    # ---- progressive precision vs single-shot full-format ----------------
    frac = FRACS[0]  # tightest budget: demand misses actually happen
    base = plan_store(cfg, freqs, vram_gb=frac * dense / 2 ** 30,
                      host_gb=0.05, progressive=False)
    prog = plan_store(cfg, freqs, vram_gb=frac * dense / 2 ** 30,
                      host_gb=0.05, progressive=True)
    pipe_b, stall_b, _ = _decode(cfg, params, thr, freqs, base, device, link)
    pipe_p, stall_p, _ = _decode(cfg, params, thr, freqs, prog, device, link)
    sp = pipe_p.sched.stats
    csv_rows.append((
        "memory/progressive_stall_reduction", 0.0,
        f"{(1.0 - stall_p / max(stall_b, 1e-12)):.1%} "
        f"(single-shot {stall_b * 1e3:.3f}ms -> progressive "
        f"{stall_p * 1e3:.3f}ms/token; drafts={sp.draft_fetches} "
        f"refined={sp.refines_applied} draft_served={sp.draft_served})"))

    # ---- disk tier: tiny host budget forces disk→host prefill ------------
    plan = plan_store(cfg, freqs, vram_gb=FRACS[1] * dense / 2 ** 30,
                      host_gb=2e-5)
    pipe_d, stall_d, _ = _decode(cfg, params, thr, freqs, plan, device, link)
    es = pipe_d.engine.summary()
    hs = pipe_d.host_tier.stats
    csv_rows.append((
        "memory/disk_tier_pressure", 0.0,
        f"stall/token={stall_d * 1e3:.3f}ms disk_s={es['disk_s'] * 1e3:.2f}ms"
        f" host_hit_rate={hs.hit_rate:.2f} "
        f"disk_reads={pipe_d.host_tier.disk.stats.reads}"))

    # ---- scenario-driven serving cell: tight arena under live traffic ----
    # the committed diurnal_mix scenario served against an arena held at
    # 1.2x the int2 floor: residency churns under a real arrival process
    # (prefill bursts + multi-tenant decode), and the arena must absorb
    # it WITHOUT overcommitting — `arena_overcommit` (the counter that
    # fires when every resident key is pinned or the pool overflows)
    # must stay 0, pinned as an acceptance row
    import dataclasses as _dc
    import os
    from repro.deploy import (DeploymentSpec, ModelSpec, ResourceSpec,
                              RuntimeSpec, ServingSpec, build)
    from repro.workload import ScenarioSpec
    scen = _dc.replace(ScenarioSpec.load(os.path.join(
        os.path.dirname(__file__), os.pardir, "examples", "scenarios",
        "diurnal_mix.json")), n_requests=12)
    model = ModelSpec(arch="mixtral-8x7b", layers=4, d_model=64,
                      max_experts=8)
    small = DeploymentSpec(model=model).resolve_config()
    tight = 1.2 * floor_bytes(small, ("int2",)) / 2 ** 30
    dep = build(DeploymentSpec(
        model=model,
        resources=ResourceSpec(vram_gb=tight, host_gb=0.05,
                               ladder=("int2",), progressive=False),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=64, policy="slo",
                            online_train=False)))
    dep.serve(scenario=scen)
    srep = dep.controller.report()
    over = sum(r.stats.arena_overcommit
               for r in dep.pipeline.sched.residency if r is not None)
    stall_tok = (sum(m.stall_s for m in dep.pipeline.metrics)
                 / max(len(dep.pipeline.metrics), 1))
    csv_rows.append((
        f"memory/scenario/{scen.name}/arena=1.20x_floor", 0.0,
        f"slo={srep['slo_attainment']:.0%} stall/token="
        f"{stall_tok * 1e3:.3f}ms rej={srep['rejected']}"))
    csv_rows.append((
        "memory/scenario_no_overcommit", 0.0,
        f"{over == 0} (arena_overcommit={over} after "
        f"{scen.n_requests}-request {scen.name} serve at 1.2x floor)"))

    # ---- the real Mixtral-8x7B config, planner-solved --------------------
    big = get_config("mixtral_8x7b")
    zipf = 1.0 / np.arange(1, big.num_experts + 1) ** 1.1
    bfreq = np.tile(zipf / zipf.sum(), (big.num_layers, 1))
    rng = np.random.default_rng(0)
    bfreq = np.take_along_axis(
        bfreq, rng.permuted(
            np.tile(np.arange(big.num_experts), (big.num_layers, 1)),
            axis=1), axis=1)
    big_dense = dense_residency_bytes(big) / 2 ** 30
    for gb in (11.0, 16.0, 24.0):
        plan = plan_store(big, bfreq, vram_gb=gb, host_gb=64.0)
        csv_rows.append((f"memory/mixtral_plan/vram={gb:.0f}GB", 0.0,
                         f"{plan.summary()} (paper: deploys in 11GB, "
                         f"dense={big_dense:.1f}GiB)"))
