"""Speculative big-little execution benchmark: stall wins + safety pins.

``repro.spec_exec`` answers a demand miss with an always-resident
little shadow of the expert (channel-pruned int2/int8 copy priced by
the planner) instead of stalling on the big transfer, then verifies
against the arrived big expert and rolls the request back when the
drafts diverged.  Claims pinned here, at paper-shaped budgets (Mixtral
geometry reduced, arena held at 1.2x the int2 floor, link narrowed to
1/16 of the paper-scaled bandwidth so a demand miss actually stalls):

* **stall win** — serving with speculation ON spends strictly less
  stalled time per generated token than the same workload served by a
  deployment built WITHOUT a speculation section, even though the
  shadow bank consumes arena budget the baseline spends on pins.
* **divergence bounded** — every accepted speculation verified at
  relative-L2 divergence <= the spec's ``max_divergence``; the pin
  replays the ``spec.divergence`` -> ``spec.accept`` event stream, so
  it audits the executor's actual decisions, not its intentions.
* **off is noop** — a deployment whose spec carries a speculation
  section but which serves with ``speculate=False`` emits a bitwise
  identical token stream AND event timeline to a deployment whose spec
  never had the section (budget chosen so shadows fill leftover arena
  without displacing pins; the plans' pinned sets are asserted equal).
* **rollback bitwise** — with ``max_divergence=1e-12`` essentially
  every speculation is rejected, so every output token is re-decoded
  from the big expert: the token streams match the never-speculated
  run bitwise.  Rollback is the recovery path; this pins that it is
  lossless, not approximately right.

Micro rows time the divergence-predictor hot path and one shadow-bank
build (us_per_call).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.offload import LinkModel
from repro.core.pipeline import paper_scaled_models
from repro.deploy import (DeploymentSpec, ModelSpec, ResourceSpec,
                          RuntimeSpec, ServingSpec, SpeculationSpec, build)
from repro.store import floor_bytes

_CACHE: dict = {}


def _setup():
    if "setup" in _CACHE:
        return _CACHE["setup"]
    probe = DeploymentSpec(model=ModelSpec(arch="mixtral-8x7b", layers=4,
                                           d_model=64, max_experts=8))
    cfg = probe.resolve_config()
    device, link0 = paper_scaled_models(cfg)
    # 1/16 of paper bandwidth: a demand miss on an unpinned expert is
    # expensive enough that answering from the shadow matters
    link = LinkModel(peak_bw=link0.peak_bw / 16, launch_us=link0.launch_us,
                     pack_bw=link0.pack_bw / 16)
    vram_gb = 1.2 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    _CACHE["setup"] = (cfg, device, link, vram_gb)
    return _CACHE["setup"]


def _spec(vram_gb: float, speculation=None) -> DeploymentSpec:
    return DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=64,
                        max_experts=8),
        resources=ResourceSpec(vram_gb=vram_gb, host_gb=0.05,
                               ladder=("int2",), progressive=False),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=64, policy="slo",
                            online_train=False),
        speculation=speculation)


class _Timeline:
    """Event consumer recording a bitwise-comparable event log."""

    def __init__(self):
        self.rows: list = []

    def on_event(self, ev) -> None:
        self.rows.append((ev.name, ev.t, ev.cat, ev.dur,
                          tuple(sorted((k, repr(v))
                                       for k, v in ev.args.items()))))


class _SpecAudit:
    """Pairs each ``spec.divergence`` with the accept/rollback verdict
    that follows it for the same (layer, expert)."""

    def __init__(self):
        self.pending: dict = {}
        self.accepted: list = []
        self.rolled: list = []

    def on_event(self, ev) -> None:
        if ev.name == "spec.divergence":
            self.pending[(ev.args["layer"], ev.args["expert"])] = \
                float(ev.args["divergence"])
        elif ev.name in ("spec.accept", "spec.rollback"):
            div = self.pending.pop((ev.args["layer"], ev.args["expert"]),
                                   None)
            if div is None:
                return
            (self.accepted if ev.name == "spec.accept"
             else self.rolled).append(div)


def _serve_arm(spec: DeploymentSpec, *, speculate=None, consumers=()):
    from repro import obs
    cfg, device, link, _ = _setup()
    dep = build(spec, device=device, link=link)
    with obs.consumer(*consumers) if consumers else _null():
        dep.serve(n_requests=10, rate=6.0, max_new=10, seed=7,
                  speculate=speculate)
    ctl = dep.controller
    stall = dep.pipeline.sched.stats.stall_s
    tokens = max(sum(len(r.output) for r in ctl.completed), 1)
    outs = {r.uid: tuple(r.output) for r in ctl.completed}
    return stall / tokens, outs, dep


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def run(csv_rows: list):
    cfg, device, link, vram_gb = _setup()

    # ---- stall win: speculation on vs a never-speculative build ----------
    audit = _SpecAudit()
    on_stall, on_outs, dep_on = _serve_arm(
        _spec(vram_gb, SpeculationSpec()), consumers=(audit,))
    off_stall, off_outs, _ = _serve_arm(_spec(vram_gb))
    rep = dep_on._speculator.report()
    csv_rows.append(("speculate/stall_per_token_ms/off", 0.0,
                     f"{off_stall * 1e3:.4f}"))
    csv_rows.append(("speculate/stall_per_token_ms/on", 0.0,
                     f"{on_stall * 1e3:.4f}"))
    csv_rows.append((
        "speculate/loop", 0.0,
        f"served={rep['spec_served']} accepts={rep['spec_accepts']} "
        f"rollbacks={rep['spec_rollbacks']} declined={rep['spec_declined']} "
        f"accept_rate={rep['spec_accept_rate']:.2f}"))
    win = on_stall < off_stall and rep["spec_served"] > 0
    csv_rows.append((
        "speculate/stall_win", 0.0,
        f"{win} (stall/token {off_stall * 1e3:.4f} -> "
        f"{on_stall * 1e3:.4f}ms with {rep['spec_served']} speculations; "
        f"acceptance: speculation strictly lower, even paying the shadow "
        f"bank's arena bytes)"))

    # ---- divergence bounded: audit the accept decisions themselves -------
    max_div = SpeculationSpec().max_divergence
    worst = max(audit.accepted) if audit.accepted else 0.0
    bounded = all(d <= max_div for d in audit.accepted)
    csv_rows.append((
        "speculate/divergence_bounded", 0.0,
        f"{bounded} (accepts={len(audit.accepted)} "
        f"rollbacks={len(audit.rolled)} worst_accepted={worst:.2e} "
        f"<= max_divergence={max_div:g})"))

    # ---- off is noop: section + speculate=False == no section, bitwise ---
    # Budget generous enough that shadows fill LEFTOVER arena: both plans
    # pin the same experts, so any timeline difference would be the
    # disabled machinery leaking into the run.
    roomy = 3.0 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    p_with = build(_spec(roomy, SpeculationSpec()), device=device,
                   link=link).plan
    p_without = build(_spec(roomy), device=device, link=link).plan
    same_pins = (p_with.pinned == p_without.pinned
                 and len(p_with.shadows) > 0)
    tl_a, tl_b = _Timeline(), _Timeline()
    _, outs_a, _ = _serve_arm(_spec(roomy, SpeculationSpec()),
                              speculate=False, consumers=(tl_a,))
    _, outs_b, _ = _serve_arm(_spec(roomy), consumers=(tl_b,))
    noop = outs_a == outs_b and tl_a.rows == tl_b.rows and same_pins
    csv_rows.append((
        "speculate/off_is_noop", 0.0,
        f"{noop} (outputs_equal={outs_a == outs_b} "
        f"timeline_equal={tl_a.rows == tl_b.rows} "
        f"events={len(tl_b.rows)} same_pins={same_pins} "
        f"shadows_planned={len(p_with.shadows)})"))

    # ---- rollback bitwise: reject everything, match the off arm ----------
    strict = SpeculationSpec(max_divergence=1e-12)
    rb_stall, rb_outs, dep_rb = _serve_arm(_spec(vram_gb, strict))
    rb_rep = dep_rb._speculator.report()
    rb_ok = (rb_outs == off_outs and rb_rep["spec_rollbacks"] > 0)
    csv_rows.append((
        "speculate/rollback_bitwise", 0.0,
        f"{rb_ok} (outputs_equal={rb_outs == off_outs} "
        f"rollbacks={rb_rep['spec_rollbacks']} "
        f"served={rb_rep['spec_served']}; acceptance: every rejected "
        f"speculation re-decodes to exactly the never-speculated stream)"))

    # ---- micro: predictor hot path + shadow bank build -------------------
    from repro.spec_exec import DivergencePredictor, build_shadow_bank
    pred = DivergencePredictor()
    rng = np.random.default_rng(0)
    divs = rng.random(512) * 0.1
    n = 2000
    t0 = time.perf_counter()
    for i in range(n):
        pred.update(i % 4, i % 8, float(divs[i % 512]))
        pred.gate(i % 4, i % 8, 0.05)
    csv_rows.append(("speculate/predictor_us_per_call",
                     (time.perf_counter() - t0) / n * 1e6,
                     f"keys={len(pred.snapshot()['experts'])}"))

    dep = build(_spec(vram_gb, SpeculationSpec()), device=device, link=link)
    from repro.core.pipeline import _unstack_layers
    layers = _unstack_layers(dep.params, dep.cfg)
    t0 = time.perf_counter()
    bank = build_shadow_bank(layers, dep.plan)
    build_us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("speculate/bank_build_us", build_us,
                     f"shadows={len(bank)}"))
