"""Diff two ``BENCH_<suite>.json`` files and flag >10% regressions.

    python benchmarks/compare.py BENCH_prefetch.old.json BENCH_prefetch.json
    python benchmarks/compare.py old/ new/ --threshold 0.15

Rows are matched by name.  Two numeric channels are compared per row:

* ``us_per_call`` — wall-clock microseconds; HIGHER is a regression.
* ``derived`` — compared only when numeric in BOTH files (``run.py``
  records it as a number whenever it parses as one).  Direction is
  metric-specific, so a change beyond the threshold is flagged as a
  CHANGE for a human to judge, not auto-classified — EXCEPT boolean
  acceptance pins: a derived value flipping from ``True...`` to
  ``False...`` (e.g. ``cluster/stall_strictly_decreasing``,
  ``multimodel/shared_stall_no_worse``) is a REGRESSION, since those
  rows encode pass/fail claims, not tunable metrics.

When both files embed a ``metrics`` snapshot (``run.py`` records the
per-suite repro.obs registry), those are diffed too: an increase in
``stall.conservation_violations`` is a REGRESSION; any other metric
moving beyond the threshold is a METRIC change for a human to judge.
Entries where both values are below 1e-6 in magnitude are exempt
(sub-microsecond simulated-timer noise).  Histograms past the
``hist_bound`` reservoir threshold stamp ``<name>.reservoir: true`` in
the snapshot; their ``.p50``/``.p99`` are SAMPLED estimates, so those
keys are exempt from flagging entirely — ``.count``/``.sum``/``.mean``/
``.max`` stay exact in reservoir mode and stay gated.

Exit status is 1 when any REGRESSION was flagged (CI gate), 0 otherwise.
Directory arguments compare every ``BENCH_*.json`` present in both.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

#: us_per_call below this is timer noise, never flagged (microseconds)
MIN_US = 1.0

#: metric values where BOTH sides are below this magnitude are exempt
#: (sub-microsecond simulated-timer noise)
MIN_METRIC = 1e-6


def load_rows(path: Path) -> dict:
    data = json.loads(path.read_text())
    return {r["name"]: r for r in data.get("rows", [])}


def load_metrics(path: Path) -> dict:
    return json.loads(path.read_text()).get("metrics") or {}


def compare_metrics(old_path: Path, new_path: Path,
                    threshold: float) -> tuple[list, list]:
    """(regressions, changes) over the embedded metrics snapshots."""
    old, new = load_metrics(old_path), load_metrics(new_path)
    if not old or not new:  # at least one side predates metric embedding
        return [], []
    regressions, changes = [], []
    for key in sorted(set(old) | set(new)):
        ov, nv = old.get(key, 0), new.get(key, 0)
        if key.endswith(".reservoir"):
            continue  # sampling-mode marker, not a metric
        if key.endswith((".p50", ".p99")):
            base = key.rsplit(".", 1)[0]
            if old.get(f"{base}.reservoir") or new.get(f"{base}.reservoir"):
                continue  # reservoir-sampled percentile: estimate, exempt
        if not (isinstance(ov, (int, float)) and
                isinstance(nv, (int, float))):
            if ov != nv:
                changes.append(f"METRIC     {key}: {ov!r} -> {nv!r}")
            continue
        if abs(float(ov)) < MIN_METRIC and abs(float(nv)) < MIN_METRIC:
            continue
        if key == "stall.conservation_violations" and nv > ov:
            regressions.append(
                f"REGRESSION {key}: {ov} -> {nv} (stall cause segments "
                f"no longer sum to the stalled seconds)")
            continue
        dd = rel_delta(float(ov), float(nv))
        if abs(dd) > threshold:
            changes.append(f"METRIC     {key}: {ov} -> {nv} ({dd:+.0%})")
    return regressions, changes


def rel_delta(old: float, new: float) -> float:
    if old == 0.0:
        return 0.0 if new == 0.0 else float("inf")
    return (new - old) / abs(old)


def compare_suite(old_path: Path, new_path: Path,
                  threshold: float) -> tuple[list, list]:
    """(regressions, changes) — lists of printable row verdicts."""
    old, new = load_rows(old_path), load_rows(new_path)
    regressions, changes = [], []
    for name in sorted(set(old) | set(new)):
        if name not in old:
            changes.append(f"NEW        {name}")
            continue
        if name not in new:
            changes.append(f"REMOVED    {name}")
            continue
        o, n = old[name], new[name]
        du = rel_delta(float(o["us_per_call"]), float(n["us_per_call"]))
        if (du > threshold and
                max(float(o["us_per_call"]),
                    float(n["us_per_call"])) >= MIN_US):
            regressions.append(
                f"REGRESSION {name}: us_per_call "
                f"{o['us_per_call']:.2f} -> {n['us_per_call']:.2f} "
                f"(+{du:.0%})")
        od, nd = o.get("derived"), n.get("derived")
        if (isinstance(od, (int, float)) and isinstance(nd, (int, float))
                and not isinstance(od, bool) and not isinstance(nd, bool)):
            dd = rel_delta(float(od), float(nd))
            if abs(dd) > threshold:
                changes.append(
                    f"CHANGE     {name}: derived {od} -> {nd} ({dd:+.0%})")
        elif (isinstance(od, str) and isinstance(nd, str)
                and od.startswith("True") and nd.startswith("False")):
            regressions.append(
                f"REGRESSION {name}: acceptance pin flipped "
                f"{od!r} -> {nd!r}")
        elif od != nd:
            changes.append(f"CHANGE     {name}: derived {od!r} -> {nd!r}")
    return regressions, changes


def _pairs(old: Path, new: Path) -> list[tuple[Path, Path]]:
    if old.is_dir() != new.is_dir():
        sys.exit("compare.py: OLD and NEW must both be BENCH json files "
                 "or both be directories of them")
    if old.is_dir():
        names = (sorted(p.name for p in old.glob("BENCH_*.json")
                        if (new / p.name).exists()))
        if not names:
            sys.exit(f"compare.py: no BENCH_*.json common to "
                     f"{old} and {new}")
        return [(old / n, new / n) for n in names]
    return [(old, new)]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("old", type=Path,
                    help="baseline BENCH_<suite>.json (or a directory)")
    ap.add_argument("new", type=Path,
                    help="candidate BENCH_<suite>.json (or a directory)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative delta that flags a row (default 10%%)")
    args = ap.parse_args()

    n_reg = 0
    for old_path, new_path in _pairs(args.old, args.new):
        regressions, changes = compare_suite(old_path, new_path,
                                             args.threshold)
        m_reg, m_chg = compare_metrics(old_path, new_path, args.threshold)
        regressions += m_reg
        changes += m_chg
        header = f"== {old_path.name} vs {new_path.name} =="
        if regressions or changes:
            print(header)
        for line in regressions + changes:
            print(f"  {line}")
        if not regressions and not changes:
            print(f"{header} no deltas beyond {args.threshold:.0%}")
        n_reg += len(regressions)
    if n_reg:
        print(f"{n_reg} regression(s) beyond "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
