"""Serving control-plane benchmark: SLO attainment under load.

Sweeps arrival rate × SLO over the same workload for two control planes:

* ``static`` — fixed batches run to completion in arrival order (the old
  one-batch-at-a-time offloaded serve loop), and
* ``slo``    — the continuous-batching controller (EDF admission,
  swap-in/out between decode steps, deadline-pressure preemption),

both on identical decode machinery and timing models, so the delta is
pure control plane.  The acceptance bar: the controller's SLO attainment
must be >= static's at every sweep point while token throughput (tokens
per modeled busy second) stays within 10%.

A second section compares prefetch recall with the router-reuse fallback
vs the online-trained residual inter-predictor: two controllers serve an
identical two-phase workload; one trains during phase 1, and phase-2
recall (stats reset at the boundary) is compared.

A third section drives the controller from committed ``repro.workload``
scenarios (``examples/scenarios/``): per-tenant SLO attainment under the
diurnal + flash-crowd traffic mixes, and the stall-cause composition
shift a drifting router distribution induces (total-variation distance
between the attribution mix of the run's two halves).
"""
from __future__ import annotations

import numpy as np

from repro.core.pipeline import paper_scaled_models

_CACHE: dict = {}


def _setup():
    """Random-init reduced Mixtral: routing varies with the sampled token
    stream (temperature > 0), so prediction quality actually moves the
    prefetch numbers — a briefly-trained micro model collapses to a few
    hot experts and every policy saturates."""
    if "m" in _CACHE:
        return _CACHE["m"]
    import jax
    import jax.numpy as jnp
    from benchmarks.bench_e2e_decode import _thresholds
    from repro.common.config import reduced
    from repro.configs import get_config
    from repro.models import transformer as tf
    cfg = reduced(get_config("mixtral_8x7b"), layers=4, d_model=64)
    params = tf.init_model(jax.random.PRNGKey(1), cfg, jnp.float32)
    thr = _thresholds(cfg, params)
    _CACHE["m"] = (cfg, params, thr)
    return _CACHE["m"]


_uid_base = 0  # bench-wide uid sequence — all uids come from the generator


def _workload(cfg, n: int, rate: float, slo_ms: float, seed: int,
              max_new: int = 6, t0: float = 0.0, jitter: bool = False):
    """Poisson arrivals via the ``repro.workload`` generator; ``jitter``
    draws heterogeneous output lengths in [max(2, max_new // 3), max_new]
    — mixed lengths are exactly where run-to-completion batching loses
    (short requests wait on long batch mates, queued requests wait on
    whole batches).  uids are allocated centrally from a bench-wide
    sequence (the old ``seed * 1000 + i`` scheme collided at n >= 1000;
    the controller now rejects duplicates at submit)."""
    global _uid_base
    from repro.workload import (ArrivalSpec, ScenarioSpec, TenantSpec,
                                generate_requests)
    spec = ScenarioSpec(
        name=f"sweep_seed{seed}", seed=seed, n_requests=n,
        arrival=ArrivalSpec(kind="poisson", rate=rate),
        tenants=(TenantSpec(
            name="bench", slo_ms=slo_ms, prompt_len_min=8,
            prompt_len_max=8,
            max_new_min=max(2, max_new // 3) if jitter else max_new,
            max_new_max=max_new, temperature=0.8, session_len=1,
            router_bias=0.9, bias_seed=seed),))
    reqs = generate_requests(spec, cfg.vocab_size, uid_base=_uid_base)
    _uid_base += len(reqs)
    for r in reqs:
        r.arrival_t += t0
    return reqs


def _controller(cfg, params, thr, device, link, *, policy: str,
                online: bool, slots: int = 2, cache_slots: int = 2):
    from repro.serving import ServingController
    return ServingController(
        params, cfg, thresholds=thr, slots=slots, max_len=128,
        policy=policy, online_train=online, train_every_tokens=24,
        train_window=256, min_train_rows=48, train_steps=300,
        offload_opts=dict(device=device, link=link,
                          cache_slots=cache_slots))


def run(csv_rows: list, n_requests: int = 8):
    cfg, params, thr = _setup()
    device, link = paper_scaled_models(cfg)

    # ---- control plane sweep: arrival rate x SLO -------------------------
    for rate, slo_ms in ((0.8, 3500.0), (1.0, 2500.0)):
        results = {}
        for policy in ("static", "slo"):
            ctl = _controller(cfg, params, thr, device, link,
                              policy=policy, online=False)
            for r in _workload(cfg, n_requests, rate, slo_ms, seed=7,
                               max_new=12, jitter=True):
                ctl.submit(r)
            ctl.run()
            rep = ctl.report()
            results[policy] = rep
            tag = f"rate={rate}_slo={slo_ms:.0f}ms/{policy}"
            csv_rows.append((
                f"serving/attainment/{tag}", 0.0,
                f"slo={rep['slo_attainment']:.0%} "
                f"tps={rep['tokens_per_s']:.1f} "
                f"ttft_p99={rep['ttft_ms_p99']:.0f}ms "
                f"preempt={rep['preemptions']} rej={rep['rejected']}"))
        gain = (results["slo"]["slo_attainment"] -
                results["static"]["slo_attainment"])
        tps_ratio = (results["slo"]["tokens_per_s"] /
                     max(results["static"]["tokens_per_s"], 1e-9))
        csv_rows.append((
            f"serving/controller_vs_static/rate={rate}", 0.0,
            f"attainment_gain={gain:+.0%} tps_ratio={tps_ratio:.2f} "
            f"(acceptance: gain>=0 at tps_ratio~1)"))

    # ---- trained inter-predictor vs router-reuse fallback ----------------
    # Phase 1: both controllers serve the same workload (one trains).
    # Phase 2: identical eval workload with prediction/staging stats reset
    # at the boundary.  The primary metric is PREDICTION recall — the
    # fraction of true routed experts the prefetcher named, graded at
    # reconcile time — which measures the predictor rather than cache-
    # capacity luck; staged recall and stall are reported alongside.
    recalls = {}
    for name, online in (("reuse_fallback", False), ("trained", True)):
        ctl = _controller(cfg, params, thr, device, link,
                          policy="slo", online=online, cache_slots=3)
        for r in _workload(cfg, 8, 4.0, 1e7, seed=11, max_new=14):
            ctl.submit(r)  # phase 1: the online controller trains here
        ctl.run()
        ctl.sched.reset_stats()
        ctl.reset_pred_stats()
        stall0, tok0 = ctl.stats["busy_s"], ctl.stats["tokens"]
        m0 = len(ctl.metrics)
        t0 = ctl.sched.clock
        for r in _workload(cfg, 6, 4.0, 1e7, seed=12, max_new=8, t0=t0):
            ctl.submit(r)  # phase 2: identical eval workload
        ctl.run()
        stall = sum(m.stall_s for m in ctl.metrics[m0:])
        toks = max(ctl.stats["tokens"] - tok0, 1)
        recalls[name] = ctl.prediction_recall()
        csv_rows.append((
            f"serving/prefetch_recall/{name}", 0.0,
            f"pred_recall={recalls[name]:.3f} "
            f"staged_recall={ctl.sched.prefetch_recall():.3f} "
            f"stall/token={1e3 * stall / toks:.2f}ms "
            f"train_rounds={ctl.train_rounds} "
            f"calib={ctl.calibrator.scale:.2f}"))
    delta = recalls["trained"] - recalls["reuse_fallback"]
    csv_rows.append((
        "serving/prefetch_recall/trained_vs_fallback", 0.0,
        f"delta={delta:+.3f} (acceptance: > 0)"))

    # ---- traffic scenarios (committed repro.workload specs) --------------
    global _uid_base
    import os
    from repro.workload import ScenarioSpec, generate_requests
    scen_dir = os.path.join(os.path.dirname(__file__), os.pardir,
                            "examples", "scenarios")

    # Per-tenant SLO attainment under the diurnal and flash-crowd mixes:
    # one controller per scenario, tenants reported separately (tight
    # chat SLOs vs relaxed long-context SLOs attain differently).
    for fname in ("diurnal_mix.json", "flash_crowd.json"):
        spec = ScenarioSpec.load(os.path.join(scen_dir, fname))
        ctl = _controller(cfg, params, thr, device, link,
                          policy="slo", online=False)
        reqs = generate_requests(spec, cfg.vocab_size, uid_base=_uid_base)
        _uid_base += len(reqs)
        for r in reqs:
            ctl.submit(r)
        ctl.run()
        rep = ctl.report()
        for tname, t in ctl.tenant_report().items():
            csv_rows.append((
                f"serving/scenario/{spec.name}/tenant={tname}", 0.0,
                f"attainment={t['slo_attainment']:.0%} "
                f"completed={t['completed']} rejected={t['rejected']} "
                f"ttft={t['ttft_ms_mean']:.0f}ms"))
        csv_rows.append((
            f"serving/scenario/{spec.name}", 0.0,
            f"slo={rep['slo_attainment']:.0%} "
            f"tps={rep['tokens_per_s']:.1f} "
            f"preempt={rep['preemptions']} rej={rep['rejected']} "
            f"(acceptance: per-tenant rows recorded)"))

    # Stall-cause composition shift under drift: serve the drifting
    # scenario's two halves on fresh controllers and compare their
    # normalized stall-attribution mixes (total-variation distance).
    # The rotated router distribution stresses different experts late
    # in the run, so the attribution composition must move.
    spec = ScenarioSpec.load(os.path.join(scen_dir, "drift_rotate.json"))
    reqs = generate_requests(spec, cfg.vocab_size, uid_base=_uid_base)
    _uid_base += len(reqs)
    mixes = []
    for half in (reqs[:len(reqs) // 2], reqs[len(reqs) // 2:]):
        ctl = _controller(cfg, params, thr, device, link,
                          policy="slo", online=False)
        for r in half:
            ctl.submit(r)
        ctl.run()
        causes = dict(ctl.sched.attribution.snapshot()["causes"])
        total = sum(causes.values())
        mixes.append({k: v / total for k, v in causes.items()}
                     if total > 0 else {})
    keys = set(mixes[0]) | set(mixes[1])
    tv = 0.5 * sum(abs(mixes[0].get(k, 0.0) - mixes[1].get(k, 0.0))
                   for k in keys)
    tops = [max(m, key=m.get) if m else "none" for m in mixes]
    csv_rows.append((
        "serving/scenario/drift_rotate/shift", 0.0,
        f"tv_distance={tv:.3f} early_top={tops[0]} late_top={tops[1]} "
        f"causes={len(keys)} (acceptance: tv > 0 under drift)"))
