"""Serving control-plane benchmark: SLO attainment under load.

Sweeps arrival rate × SLO over the same workload for two control planes:

* ``static`` — fixed batches run to completion in arrival order (the old
  one-batch-at-a-time offloaded serve loop), and
* ``slo``    — the continuous-batching controller (EDF admission,
  swap-in/out between decode steps, deadline-pressure preemption),

both on identical decode machinery and timing models, so the delta is
pure control plane.  The acceptance bar: the controller's SLO attainment
must be >= static's at every sweep point while token throughput (tokens
per modeled busy second) stays within 10%.

A second section compares prefetch recall with the router-reuse fallback
vs the online-trained residual inter-predictor: two controllers serve an
identical two-phase workload; one trains during phase 1, and phase-2
recall (stats reset at the boundary) is compared.
"""
from __future__ import annotations

import numpy as np

from repro.core.pipeline import paper_scaled_models

_CACHE: dict = {}


def _setup():
    """Random-init reduced Mixtral: routing varies with the sampled token
    stream (temperature > 0), so prediction quality actually moves the
    prefetch numbers — a briefly-trained micro model collapses to a few
    hot experts and every policy saturates."""
    if "m" in _CACHE:
        return _CACHE["m"]
    import jax
    import jax.numpy as jnp
    from benchmarks.bench_e2e_decode import _thresholds
    from repro.common.config import reduced
    from repro.configs import get_config
    from repro.models import transformer as tf
    cfg = reduced(get_config("mixtral_8x7b"), layers=4, d_model=64)
    params = tf.init_model(jax.random.PRNGKey(1), cfg, jnp.float32)
    thr = _thresholds(cfg, params)
    _CACHE["m"] = (cfg, params, thr)
    return _CACHE["m"]


def _workload(cfg, n: int, rate: float, slo_ms: float, seed: int,
              max_new: int = 6, t0: float = 0.0, jitter: bool = False):
    """Poisson arrivals; ``jitter`` draws heterogeneous output lengths in
    [max(2, max_new // 3), max_new] — mixed lengths are exactly where
    run-to-completion batching loses (short requests wait on long batch
    mates, queued requests wait on whole batches)."""
    from repro.serving import SLORequest
    rng = np.random.default_rng(seed)
    reqs = []
    t = t0
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        mn = (int(rng.integers(max(2, max_new // 3), max_new + 1))
              if jitter else max_new)
        reqs.append(SLORequest(
            uid=seed * 1000 + i,
            prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=mn, slo_ms=slo_ms, arrival_t=t,
            temperature=0.8))
    return reqs


def _controller(cfg, params, thr, device, link, *, policy: str,
                online: bool, slots: int = 2, cache_slots: int = 2):
    from repro.serving import ServingController
    return ServingController(
        params, cfg, thresholds=thr, slots=slots, max_len=128,
        policy=policy, online_train=online, train_every_tokens=24,
        train_window=256, min_train_rows=48, train_steps=300,
        offload_opts=dict(device=device, link=link,
                          cache_slots=cache_slots))


def run(csv_rows: list, n_requests: int = 8):
    cfg, params, thr = _setup()
    device, link = paper_scaled_models(cfg)

    # ---- control plane sweep: arrival rate x SLO -------------------------
    for rate, slo_ms in ((0.8, 3500.0), (1.0, 2500.0)):
        results = {}
        for policy in ("static", "slo"):
            ctl = _controller(cfg, params, thr, device, link,
                              policy=policy, online=False)
            for r in _workload(cfg, n_requests, rate, slo_ms, seed=7,
                               max_new=12, jitter=True):
                ctl.submit(r)
            ctl.run()
            rep = ctl.report()
            results[policy] = rep
            tag = f"rate={rate}_slo={slo_ms:.0f}ms/{policy}"
            csv_rows.append((
                f"serving/attainment/{tag}", 0.0,
                f"slo={rep['slo_attainment']:.0%} "
                f"tps={rep['tokens_per_s']:.1f} "
                f"ttft_p99={rep['ttft_ms_p99']:.0f}ms "
                f"preempt={rep['preemptions']} rej={rep['rejected']}"))
        gain = (results["slo"]["slo_attainment"] -
                results["static"]["slo_attainment"])
        tps_ratio = (results["slo"]["tokens_per_s"] /
                     max(results["static"]["tokens_per_s"], 1e-9))
        csv_rows.append((
            f"serving/controller_vs_static/rate={rate}", 0.0,
            f"attainment_gain={gain:+.0%} tps_ratio={tps_ratio:.2f} "
            f"(acceptance: gain>=0 at tps_ratio~1)"))

    # ---- trained inter-predictor vs router-reuse fallback ----------------
    # Phase 1: both controllers serve the same workload (one trains).
    # Phase 2: identical eval workload with prediction/staging stats reset
    # at the boundary.  The primary metric is PREDICTION recall — the
    # fraction of true routed experts the prefetcher named, graded at
    # reconcile time — which measures the predictor rather than cache-
    # capacity luck; staged recall and stall are reported alongside.
    recalls = {}
    for name, online in (("reuse_fallback", False), ("trained", True)):
        ctl = _controller(cfg, params, thr, device, link,
                          policy="slo", online=online, cache_slots=3)
        for r in _workload(cfg, 8, 4.0, 1e7, seed=11, max_new=14):
            ctl.submit(r)  # phase 1: the online controller trains here
        ctl.run()
        ctl.sched.reset_stats()
        ctl.reset_pred_stats()
        stall0, tok0 = ctl.stats["busy_s"], ctl.stats["tokens"]
        m0 = len(ctl.metrics)
        t0 = ctl.sched.clock
        for r in _workload(cfg, 6, 4.0, 1e7, seed=12, max_new=8, t0=t0):
            ctl.submit(r)  # phase 2: identical eval workload
        ctl.run()
        stall = sum(m.stall_s for m in ctl.metrics[m0:])
        toks = max(ctl.stats["tokens"] - tok0, 1)
        recalls[name] = ctl.prediction_recall()
        csv_rows.append((
            f"serving/prefetch_recall/{name}", 0.0,
            f"pred_recall={recalls[name]:.3f} "
            f"staged_recall={ctl.sched.prefetch_recall():.3f} "
            f"stall/token={1e3 * stall / toks:.2f}ms "
            f"train_rounds={ctl.train_rounds} "
            f"calib={ctl.calibrator.scale:.2f}"))
    delta = recalls["trained"] - recalls["reuse_fallback"]
    csv_rows.append((
        "serving/prefetch_recall/trained_vs_fallback", 0.0,
        f"delta={delta:+.3f} (acceptance: > 0)"))
