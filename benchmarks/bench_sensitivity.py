"""Paper Fig. 3a / Tables 5-6 (sparsification sensitivity) and Fig. 3b /
Table 7 (quantization sensitivity), on a small TRAINED MoE.

The paper's claims are ORDERINGS (down least sensitive <= up < gate for
sparsity; up least sensitive for quantization) — we measure model-level
perplexity on held-out synthetic data under each compression variant.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig, reduced
from repro.configs import get_config
from repro.core import hqq, sparsify
from repro.core.pipeline import _unstack_layers
from repro.data import SyntheticLM, make_batches
from repro.launch.train import train_loop
from repro.models import transformer as tf

_CACHE = {}


def trained_model(steps: int = 150):
    if "model" in _CACHE:
        return _CACHE["model"]
    cfg = reduced(get_config("mixtral_8x7b"), layers=2, d_model=128)
    tc = TrainConfig(learning_rate=2e-3, total_steps=steps,
                     warmup_steps=steps // 10)
    params, _, _ = train_loop(cfg, tc, batch=8, seq=64, steps=steps,
                              log_every=10**9)
    _CACHE["model"] = (cfg, params)
    return cfg, params


def eval_ppl(cfg, params, seed=123, batches=4):
    # SAME synthetic language as training (table seed 0), held-out streams
    src = SyntheticLM(cfg.vocab_size, seed=0)
    losses = []
    for b in make_batches(src, 8, 64, batches, seed=seed):
        loss, _ = tf.loss_fn(params, {"tokens": jnp.asarray(b["tokens"])}, cfg)
        losses.append(float(loss))
    return float(np.exp(np.mean(losses)))


def _map_moe(params, cfg, fn):
    """Apply fn(moe_params) -> moe_params to every MoE layer."""
    import copy
    out = jax.tree.map(lambda x: x, params)  # shallow-ish copy
    for si, (pattern, reps) in enumerate(cfg.segments()):
        for pi, kind in enumerate(pattern):
            if kind != "moe":
                continue
            stack = out[f"seg{si}"][f"pos{pi}"]
            stack["moe"] = fn(stack["moe"])
    return out


def _eval_sparse_impl(cfg, params, variant, sparsity):
    """Perplexity with S_t pruning of `variant` patched into every expert."""
    from repro.models import moe as moe_lib
    import repro.models.blocks as blk

    def expert_fn(xs, wg, wu, wd, group_sizes):
        g = jax.lax.ragged_dot(xs, wg, group_sizes).astype(jnp.float32)
        u = jax.lax.ragged_dot(xs, wu, group_sizes).astype(jnp.float32)
        if variant == "up":
            t = jnp.quantile(jnp.abs(u), sparsity, axis=-1, keepdims=True)
            u = sparsify.s_t(u, t)
        elif variant == "gate":
            gs = jax.nn.silu(g)
            t = jnp.quantile(jnp.abs(gs), sparsity, axis=-1, keepdims=True)
            g = jnp.where(jnp.abs(gs) >= t, g, -20.0)  # silu(-20) ~ 0
        h0 = jax.nn.silu(g) * u
        if variant == "down":
            t = jnp.quantile(jnp.abs(h0), sparsity, axis=-1, keepdims=True)
            h0 = sparsify.s_t(h0, t)
        return jax.lax.ragged_dot(h0.astype(xs.dtype), wd, group_sizes)

    src = SyntheticLM(cfg.vocab_size, seed=0)  # same language as training
    losses = []
    orig = moe_lib.moe_forward

    def patched(p, x, c, dist=None, expert_fn_=expert_fn):
        return orig(p, x, c, dist, expert_fn_)

    moe_lib.moe_forward = patched
    blk.moe_lib.moe_forward = patched
    try:
        for b in make_batches(src, 8, 64, 3, seed=123):
            loss, _ = tf.loss_fn(params,
                                 {"tokens": jnp.asarray(b["tokens"])}, cfg)
            losses.append(float(loss))
    finally:
        moe_lib.moe_forward = orig
        blk.moe_lib.moe_forward = orig
    return float(np.exp(np.mean(losses)))


def run(csv_rows: list):
    cfg, params = trained_model()
    base_ppl = eval_ppl(cfg, params)
    csv_rows.append(("fig3a/base_ppl", 0.0, f"ppl={base_ppl:.3f}"))
    xcal = jax.random.normal(jax.random.PRNGKey(77), (256, cfg.d_model)) * 0.5

    # ---- Fig 3a: sparsification sensitivity via masked-forward eval ------
    def eval_sparse(variant, sparsity):
        return _eval_sparse_impl(cfg, params, variant, sparsity)

    order_ok = []
    for sp in (0.5, 0.7, 0.9):
        ppls = {v: eval_sparse(v, sp) for v in ("gate", "up", "down")}
        order_ok.append(ppls["down"] <= ppls["up"] + 1e-6 <= ppls["gate"] + 2e-2)
        for v, p in ppls.items():
            csv_rows.append((f"fig3a/sparsity/{v}@{sp:.0%}", 0.0,
                             f"ppl={p:.3f}"))
    csv_rows.append(("fig3a/ordering_down<=up<gate", 0.0,
                     f"holds={sum(order_ok)}/{len(order_ok)}"))

    # ---- Fig 9b: sparsity x quantization compatibility -------------------
    # the paper: "errors introduced by activation sparsity and weight
    # quantization are largely independent and additive."
    def eval_floe(sparsity, bits):
        def quant_up(moe_p, bits=bits):
            w = moe_p["we_up"]
            flat = w.reshape((-1,) + w.shape[-2:])
            qt = hqq.quantize_per_expert(flat, bits=bits, group=32)
            deq = jax.vmap(lambda p, s, z: hqq.dequantize(
                hqq.QTensor(p, s, z, bits, 32, qt.shape), w.dtype))(
                qt.packed, qt.scale, qt.zero)
            out = dict(moe_p)
            out["we_up"] = deq.reshape(w.shape)
            return out
        pq = _map_moe(params, cfg, quant_up) if bits else params
        if not sparsity:
            return _eval_with_params(pq)
        return _eval_sparse_with(pq, "up", sparsity)

    def _eval_with_params(p):
        return eval_ppl(cfg, p, batches=3)

    def _eval_sparse_with(p, variant, sp):
        return _eval_sparse_impl(cfg, p, variant, sp)

    d_base = eval_floe(0.0, 0)
    d_sp = eval_floe(0.8, 0) - d_base
    d_q = eval_floe(0.0, 2) - d_base
    d_both = eval_floe(0.8, 2) - d_base
    csv_rows.append(("fig9b/quant_compat", 0.0,
                     f"d_ppl(sparse80)={d_sp:+.3f} d_ppl(INT2)={d_q:+.3f} "
                     f"d_ppl(both)={d_both:+.3f} "
                     f"additive_pred={d_sp + d_q:+.3f} (paper: independent "
                     "and additive)"))

    # ---- Fig 3b / Table 7: quantization sensitivity ----------------------
    for bits in (8, 4, 3, 2):
        for target in ("gate", "up", "down"):
            def quant(moe_p, target=target, bits=bits):
                key = {"gate": "we_gate", "up": "we_up", "down": "we_down"}[target]
                w = moe_p[key]  # (layers, E, m, n) scan-stacked
                flat = w.reshape((-1,) + w.shape[-2:])
                qt = hqq.quantize_per_expert(flat, bits=bits, group=32)
                deq = jax.vmap(lambda p, s, z: hqq.dequantize(
                    hqq.QTensor(p, s, z, bits, 32, qt.shape), w.dtype))(
                    qt.packed, qt.scale, qt.zero)
                out = dict(moe_p)
                out[key] = deq.reshape(w.shape)
                return out
            p2 = _map_moe(params, cfg, quant)
            ppl = eval_ppl(cfg, p2, batches=3)
            csv_rows.append((f"fig3b/quant/INT{bits}/{target}", 0.0,
                             f"ppl={ppl:.3f}"))
