"""Fleet-scale serving benchmark: 8 models x 4 devices x 20k requests.

The nightly-only scale lane (registered in ``run.py`` but not in the
push/PR bench loop): an eight-member fleet over one shared host tier,
each member a 4-device cluster with its own SLO control plane, served
from a single overloaded ``repro.workload`` scenario (diurnal +
flash-crowd arrivals, drifting router bias, 2 500 requests per model).
Tight SLOs mean the EDF feasibility gate rejects most of the queue —
the point is the CONTROL PLANE at scale, not 20k full decodes.

One member (model ``d``) gets a drift-heavy scenario (fast strong
rotation) AND a live re-planner: its drift triggers re-run the cluster
planner mid-serve, every re-plan is debited against the fleet's
admission ledger (``Fleet.recommit`` — a denial aborts that re-plan),
and the migrations ride the shared-tier transfer timelines while the
other members keep serving.  Re-planning under fleet contention,
pinned as "the loop ran and the run completed", not as a perf claim.

One member (model ``e``) serves with big-little SPECULATION on: its
cluster plan prices an always-resident shadow bank, demand misses are
answered from the shadows and verified-or-rolled-back when the big
expert lands — all while seven non-speculating siblings contend for
the same host tier.  The global ``fleetscale/stall_conservation`` row
(appended by ``run.py``) now also covers the ``speculative_fallback``
cause: every declined or rolled-back speculation's stalled seconds
must still sum back bitwise.

Pins:

* ``submit_subquadratic`` — per-submit cost of the second 1 250
  requests vs the first 1 250.  The heap intake is O(log n) per
  submit, so the ratio stays ~1; the old sort-on-every-submit intake
  was O(n log n) per call and blows past the 2.5x acceptance bar.
* per-model completion rows (completed / rejected / attainment) — the
  run must COMPLETE, exercising heap intake, uid uniqueness, bounded
  metrics reservoirs, and busy+idle clock conservation at 10k scale.
* ``fleetscale/replan/model=d`` — the fleet-contended replan loop:
  drift checks ran, ledger recommits were attempted, and the member
  still completed its stream.
* ``fleetscale/stall_conservation`` (appended by ``run.py``) — every
  stall event's cause segments still sum back to its stalled seconds.
"""
from __future__ import annotations

import time

from repro.deploy import (DeploymentSpec, ModelSpec, ReplanSpec,
                          ResourceSpec, RuntimeSpec, ServingSpec,
                          SpeculationSpec, build_fleet)
from repro.store import floor_bytes
from repro.workload import (ArrivalSpec, BurstSpec, DriftSpec, ScenarioSpec,
                            TenantSpec, generate_requests)

N_PER_MODEL = 2500
DEVICES = 4
MODELS = "abcdefgh"
SEEDS = tuple(range(len(MODELS)))
#: model ``d`` serves the drift-heavy scenario with this replan section
REPLAN = ReplanSpec(window=16, threshold=0.15, cooldown_s=4.0,
                    check_every=4, bandwidth_share=0.25)
#: model ``e`` serves with the big-little speculative executor attached
SPECULATE_MODEL = "e"
_CACHE: dict = {}


def _scenario(seed: int, *, drift_strength: float = 0.5,
              drift_period_s: float = 30.0) -> ScenarioSpec:
    """Overloaded production mix: diurnal base traffic, one flash
    crowd, drifting router bias, two tenants with tight SLOs."""
    return ScenarioSpec(
        name="fleetscale", seed=seed, n_requests=N_PER_MODEL,
        arrival=ArrivalSpec(
            kind="diurnal", rate=80.0, period_s=40.0, amplitude=0.5,
            bursts=(BurstSpec(start_t=20.0, duration_s=8.0,
                              multiplier=3.0),)),
        tenants=(
            TenantSpec(name="chat", weight=3.0, slo_ms=1500.0,
                       prompt_len_min=4, prompt_len_max=8,
                       max_new_min=2, max_new_max=4, temperature=0.8,
                       session_len=2, think_time_s=0.05,
                       router_bias=1.2, bias_seed=1),
            TenantSpec(name="code", weight=1.0, slo_ms=4000.0,
                       prompt_len_min=6, prompt_len_max=12,
                       max_new_min=2, max_new_max=4, temperature=0.2,
                       session_len=1, think_time_s=0.05,
                       router_bias=0.8, bias_seed=2),
        ),
        drift=DriftSpec(kind="rotate", period_s=drift_period_s,
                        strength=drift_strength))


def _spec(name: str, seed: int, vram_gb: float, host_gb: float
          ) -> DeploymentSpec:
    return DeploymentSpec(
        name=name,
        model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=64,
                        max_experts=8, seed=seed),
        resources=ResourceSpec(vram_gb=vram_gb, host_gb=host_gb,
                               devices=DEVICES, ladder=("int2",),
                               progressive=False),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=64, policy="slo",
                            online_train=False),
        replan=REPLAN if name == "d" else None,
        speculation=(SpeculationSpec() if name == SPECULATE_MODEL
                     else None))


def _setup():
    if "setup" in _CACHE:
        return _CACHE["setup"]
    probe = _spec("probe", 0, 1.0, 1.0)
    cfg = probe.resolve_config()
    vram_gb = 1.05 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    _CACHE["setup"] = (cfg, vram_gb)
    return _CACHE["setup"]


def run(csv_rows: list):
    cfg, vram_gb = _setup()
    host_gb = 0.05
    # per-device budget holds ~1.25x the four members' committed
    # footprints: enough to admit everyone at build, tight enough that
    # model d's re-plans contend for real headroom at recommit time
    # the speculating member gets 1.4x the floor so the planner's shadow
    # stage actually funds a bank after slots + pins (at 1.05x it cannot);
    # the fleet admission budget covers the sum of the members' asks
    vram_of = {name: (1.4 * vram_gb if name == SPECULATE_MODEL
                      else vram_gb) for name in MODELS}
    fleet = build_fleet(
        [_spec(name, seed, vram_of[name], host_gb / len(MODELS))
         for name, seed in zip(MODELS, SEEDS)],
        vram_gb_per_device=1.25 * sum(vram_of.values()), host_gb=host_gb)

    uid_base = 0
    streams = {}
    for name, seed in zip(MODELS, SEEDS):
        scen = (_scenario(101 + seed) if name != "d" else
                _scenario(101 + seed, drift_strength=0.9,
                          drift_period_s=15.0))
        streams[name] = generate_requests(scen, cfg.vocab_size,
                                          uid_base=uid_base)
        uid_base += len(streams[name])

    import gc
    submit_us = {}
    for name in MODELS:
        reqs = streams[name]
        ctl = fleet[name].deployment.controller
        # the fleet clock is lockstep: rebase this member's arrivals to
        # NOW so the previous member's run hasn't already blown every
        # deadline before the stream even starts
        t_base = ctl.sched.clock
        for r in reqs:
            r.arrival_t += t_base

        # intake timing: per-submit cost of the second half vs the
        # first (the heap holds 1.25k entries when the second half
        # starts — sub-quadratic intake keeps the ratio ~1, the old
        # sort-on-every-submit blew it up).  GC is paused around the
        # timed loops so a collection pause on one half doesn't
        # masquerade as algorithmic cost.
        half = len(reqs) // 2
        times = []
        gc.collect()
        gc.disable()
        try:
            for chunk in (reqs[:half], reqs[half:]):
                t0 = time.perf_counter()
                for r in chunk:
                    ctl.submit(r)
                times.append((time.perf_counter() - t0)
                             / max(len(chunk), 1))
        finally:
            gc.enable()
        submit_us[name] = [1e6 * t for t in times]

        t0 = time.perf_counter()
        fleet.serve(name, requests=())  # drain the submitted stream
        wall_s = time.perf_counter() - t0
        rep = ctl.report()
        tenants = ctl.tenant_report()
        per_tenant = " ".join(
            f"{t}:{v['slo_attainment']:.0%}" for t, v in tenants.items())
        csv_rows.append((
            f"fleetscale/model={name}", 0.0,
            f"n={len(reqs)} completed={len(ctl.completed)}"
            f" rejected={rep['rejected']} slo={rep['slo_attainment']:.0%} "
            f"per_tenant=[{per_tenant}] wall={wall_s:.1f}s"))

    # the drift-heavy member's replan loop under fleet contention
    rp = fleet["d"].deployment._replanner
    rr = rp.report() if rp is not None else {}
    ran = bool(rr) and rr.get("checks", 0) > 0
    csv_rows.append((
        "fleetscale/replan/model=d", 0.0,
        f"{ran} (checks={rr.get('checks', 0)} "
        f"triggers={rr.get('drift_triggers', 0)} "
        f"replans={rr.get('replans', 0)} denied={rr.get('denied', 0)} "
        f"migrate_transfers={rr.get('migrate_transfers', 0)} "
        f"rehomes={rr.get('migrate_rehomes', 0)}; acceptance: the "
        f"fleet-ledgered replan loop ran and the stream completed)"))

    # the speculating member: shadow bank planned, executor attached,
    # stream completed under fleet contention (the stall_conservation
    # row below then covers its speculative_fallback attributions)
    dep_e = fleet[SPECULATE_MODEL].deployment
    sp = dep_e._speculator
    sr = sp.report() if sp is not None else {}
    shadows = len(dep_e.plan.store_plan.shadows
                  if hasattr(dep_e.plan, "store_plan")
                  else dep_e.plan.shadows)
    spec_ok = sp is not None and shadows > 0
    csv_rows.append((
        f"fleetscale/speculate/model={SPECULATE_MODEL}", 0.0,
        f"{spec_ok} (shadows={shadows} served={sr.get('spec_served', 0)} "
        f"accepts={sr.get('spec_accepts', 0)} "
        f"rollbacks={sr.get('spec_rollbacks', 0)} "
        f"declined={sr.get('spec_declined', 0)}; acceptance: the "
        f"speculating member planned a shadow bank and completed its "
        f"stream alongside seven non-speculating siblings)"))

    for name in MODELS:
        first, second = submit_us[name]
        csv_rows.append((
            f"fleetscale/submit_us/model={name}/half=1", first, ""))
        csv_rows.append((
            f"fleetscale/submit_us/model={name}/half=2", second, ""))
    # acceptance on the CLEANEST model's ratio (model a submits before
    # any decode has touched the process; later members time under
    # allocator/dispatch noise from the previous run).  A quadratic
    # intake shows ratio ~3 on every model, so min() still refutes it.
    ratios = [submit_us[n][1] / max(submit_us[n][0], 1e-9)
              for n in MODELS]
    best = min(ratios)
    csv_rows.append((
        "fleetscale/submit_subquadratic", 0.0,
        f"{best < 2.0} ratio={best:.2f} "
        f"(second-half vs first-half per-submit cost at "
        f"{N_PER_MODEL} requests/model, cleanest of "
        f"{[round(r, 2) for r in ratios]}; acceptance: < 2.0)"))
