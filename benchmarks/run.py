"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table1  -> bench_sparse_kernel   (sparse GEMV latency vs sparsity)
  fig3    -> bench_sensitivity     (sparsification + quantization)
  fig4    -> bench_predictor       (similarity + dual predictors)
  fig6/8  -> bench_e2e_decode      (end-to-end decode TPS, cache sweep)
  fig7    -> bench_transfer        (compact layout + chunk-size curve)
  headline-> bench_compression     (9.3x per-expert, VRAM footprint)
  prefetch-> bench_prefetch        (runtime scheduler: overlap, stall/token)
  serving -> bench_serving         (SLO attainment: controller vs static,
                                    trained-predictor prefetch recall)
  roofline-> roofline              (dry-run derived terms, if present)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter")
    args = ap.parse_args()

    from benchmarks import (bench_compression, bench_e2e_decode,
                            bench_predictor, bench_prefetch,
                            bench_sensitivity, bench_serving,
                            bench_sparse_kernel, bench_transfer, roofline)

    suites = [
        ("headline", bench_compression.run),
        ("table1", bench_sparse_kernel.run),
        ("fig7", bench_transfer.run),
        ("fig3", bench_sensitivity.run),
        ("fig4", bench_predictor.run),
        ("fig6", bench_e2e_decode.run),
        ("prefetch", bench_prefetch.run),
        ("serving", bench_serving.run),
        ("roofline", roofline.run),
    ]
    rows: list = []
    print("name,us_per_call,derived")
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        before = len(rows)
        try:
            fn(rows)
        except Exception as e:  # keep the harness running
            traceback.print_exc()
            rows.append((f"{name}/ERROR", 0.0, repr(e)))
        for r in rows[before:]:
            print(f"{r[0]},{r[1]:.2f},{r[2]}")
        sys.stdout.flush()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
