"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and, per suite, writes a
machine-readable ``BENCH_<suite>.json`` at the repo root (suite name,
rows, timestamp, elapsed seconds) so the perf trajectory is tracked
across PRs.

  table1  -> bench_sparse_kernel   (sparse GEMV latency vs sparsity)
  fig3    -> bench_sensitivity     (sparsification + quantization)
  fig4    -> bench_predictor       (similarity + dual predictors)
  fig6/8  -> bench_e2e_decode      (end-to-end decode TPS, cache sweep)
  fig7    -> bench_transfer        (compact layout + chunk-size curve)
  headline-> bench_compression     (9.3x per-expert, VRAM footprint)
  prefetch-> bench_prefetch        (runtime scheduler: overlap, stall/token)
  serving -> bench_serving         (SLO attainment: controller vs static,
                                    trained-predictor prefetch recall)
  memory  -> bench_memory          (tiered store: footprint vs stall/token
                                    across VRAM budgets, progressive
                                    precision, disk-tier pressure)
  cluster -> bench_cluster         (multi-GPU placement: stall/token +
                                    link utilization vs device count,
                                    replication sweep)
  replan  -> bench_replan          (live re-planning: drift recovery on
                                    the rotate scenario — replan-on
                                    strictly lower stall AND higher
                                    attainment post-drift; migration
                                    decode parity; diff idempotence)
  multimodel -> bench_multimodel   (fleet: two models over one shared
                                    host/disk tier vs isolation — stall
                                    no worse, host bytes strictly lower,
                                    footprint-aware admission; scenario-
                                    driven fleet serving)
  health  -> bench_health          (live health layer: flash_crowd pages
                                    BEFORE attainment bottoms out with a
                                    byte-deterministic incident bundle,
                                    stationary diurnal_mix stays alert-
                                    free, monitor attach is zero-overhead)
  speculate -> bench_speculate    (big-little speculation: stall/token
                                    strictly below non-speculative at
                                    the tight Mixtral budget, accepted
                                    divergence bounded, speculation-off
                                    a bitwise noop, rollback re-decodes
                                    bitwise equal to never-speculated)
  fleetscale -> bench_fleetscale   (nightly scale lane: 4 models x
                                    4 devices x 10k scenario requests,
                                    one drift-heavy member replanning
                                    against the fleet ledger — sub-
                                    quadratic intake, conservation at
                                    scale; NOT in the push/PR loop)
  roofline-> roofline              (dry-run derived terms, if present)

``derived`` is recorded in the JSON as a NUMBER whenever it parses as
one (string fallback otherwise), so ``benchmarks/compare.py`` can diff
two BENCH files machine-to-machine across PRs.

Each suite runs with a ``repro.obs`` MetricsCollector attached: its
deterministic snapshot is embedded as ``metrics`` in the suite JSON
(``compare.py`` diffs it), and any suite that produced events gains a
``<suite>/stall_conservation`` acceptance row whose derived value flips
``True... -> False...`` — a CI regression — if any stall event's cause
segments fail to sum back to its stalled seconds.  ``--trace-dir DIR``
additionally exports one Perfetto trace per suite.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def derived_value(v):
    """Numeric when it parses as one (cross-PR diffable), else string."""
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, (int, float)):
        return v
    try:
        f = float(str(v).strip())
    except ValueError:
        return str(v)
    return f if (f == f and abs(f) != float("inf")) else str(v)


def write_suite_json(name: str, rows: list, timestamp: str,
                     elapsed_s: float, metrics: dict | None = None) -> Path:
    out = {
        "suite": name,
        "timestamp": timestamp,
        "elapsed_s": round(elapsed_s, 3),
        "rows": [{"name": r[0], "us_per_call": float(r[1]),
                  "derived": derived_value(r[2])} for r in rows],
    }
    if metrics:
        out["metrics"] = metrics
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(out, indent=1) + "\n")
    return path


def conservation_row(name: str, collector) -> tuple | None:
    """Per-suite acceptance pin: every stall event's cause segments must
    sum back to its stalled seconds.  Derived is ``True events=N`` /
    ``False violations=K events=N`` so a flip is a compare.py
    REGRESSION, not a judgement call."""
    reg = collector.registry.snapshot()
    events = int(reg.get("events_total", 0))
    if events == 0:  # pure-kernel suite: nothing to conserve
        return None
    violations = int(reg.get("stall.conservation_violations", 0))
    derived = (f"True events={events}" if violations == 0
               else f"False violations={violations} events={events}")
    return (f"{name}/stall_conservation", 0.0, derived)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter")
    ap.add_argument("--timestamp",
                    default=time.strftime("%Y-%m-%dT%H:%M:%S"),
                    help="stamp recorded in BENCH_<suite>.json (e.g. a "
                         "commit date, for cross-PR perf tracking)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing BENCH_<suite>.json files")
    ap.add_argument("--trace-dir", default="",
                    help="export a Perfetto trace-event JSON per suite "
                         "into this directory (trace_<suite>.json)")
    ap.add_argument("--trace-cap", type=int, default=250_000,
                    help="per-suite span cap on exported traces (most "
                         "recent events win; the fleetscale lane's 10k-"
                         "request runs otherwise grow CI artifacts "
                         "unboundedly); 0 = unbounded")
    args = ap.parse_args()

    from benchmarks import (bench_cluster, bench_compression,
                            bench_e2e_decode, bench_fleetscale,
                            bench_health, bench_memory, bench_multimodel,
                            bench_predictor, bench_prefetch,
                            bench_replan, bench_sensitivity,
                            bench_serving, bench_sparse_kernel,
                            bench_speculate, bench_transfer, roofline)

    suites = [
        ("headline", bench_compression.run),
        ("table1", bench_sparse_kernel.run),
        ("fig7", bench_transfer.run),
        ("fig3", bench_sensitivity.run),
        ("fig4", bench_predictor.run),
        ("fig6", bench_e2e_decode.run),
        ("prefetch", bench_prefetch.run),
        ("serving", bench_serving.run),
        ("memory", bench_memory.run),
        ("cluster", bench_cluster.run),
        ("replan", bench_replan.run),
        ("multimodel", bench_multimodel.run),
        ("health", bench_health.run),
        ("speculate", bench_speculate.run),
        ("fleetscale", bench_fleetscale.run),
        ("roofline", roofline.run),
    ]
    from repro import obs

    trace_dir = Path(args.trace_dir) if args.trace_dir else None
    if trace_dir is not None:
        trace_dir.mkdir(parents=True, exist_ok=True)

    rows: list = []
    print("name,us_per_call,derived")
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        before = len(rows)
        collector = obs.MetricsCollector()
        tracer = obs.Tracer(max_export=args.trace_cap or None) \
            if trace_dir is not None else None
        consumers = [collector] + ([tracer] if tracer is not None else [])
        try:
            with obs.consumer(*consumers):
                fn(rows)
        except Exception as e:  # keep the harness running
            traceback.print_exc()
            rows.append((f"{name}/ERROR", 0.0, repr(e)))
        row = conservation_row(name, collector)
        if row is not None:
            rows.append(row)
        for r in rows[before:]:
            print(f"{r[0]},{r[1]:.2f},{r[2]}")
        sys.stdout.flush()
        elapsed = time.perf_counter() - t0
        if not args.no_json:
            write_suite_json(name, rows[before:], args.timestamp, elapsed,
                             metrics=collector.registry.snapshot())
        if tracer is not None:
            n = tracer.export(trace_dir / f"trace_{name}.json")
            print(f"# {name}: {n} trace events -> "
                  f"{trace_dir / f'trace_{name}.json'}", file=sys.stderr)
        print(f"# {name} done in {elapsed:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
