"""Paper Table 1: single-expert sparse-GEMV latency vs sparsity.

On CPU we report (a) wall-clock of the jitted kernel path at Mixtral expert
shape scaled down, and (b) the DERIVED latency on the paper's GPUs from the
bytes-touched model (decode GEMV is bandwidth-bound), which is what the
table's trend actually measures.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hqq, sparsify
from repro.kernels import ops

GPUS = {  # (peak fp16 flops, HBM bytes/s)
    "H100": (989e12, 3350e9),
    "A100": (312e12, 2039e9),
    "A6000": (155e12, 768e9),
    "RTX-3090": (71e12, 936e9),
}
SPARSITIES = (0.0, 0.5, 0.6, 0.7, 0.8, 0.9)


def derived_latency_us(d: int, f: int, sparsity: float, gpu: str) -> float:
    """Dense INT2 up GEMV + sparse gate/down GEMVs, bandwidth-bound."""
    flops_peak, bw = GPUS[gpu]
    keep = 1.0 - sparsity
    up_bytes = d * f * 0.25 + (d // 64) * f * 8  # packed + scale/zero
    gd_bytes = 2 * d * f * keep * 2  # fp16 gate cols + down rows
    fixed_us = 8.0  # kernel launches + activation traffic
    return (up_bytes + gd_bytes) / bw * 1e6 + fixed_us


def run(csv_rows: list, *, d: int = 512, f: int = 1792, trials: int = 5):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, d), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (d, f)) * 0.05
    qt = hqq.quantize(w, bits=2, group=64)
    wg = jax.random.normal(jax.random.PRNGKey(2), (d, f)) * 0.05
    wd = jax.random.normal(jax.random.PRNGKey(3), (f, d)) * 0.05
    v_full = x @ hqq.dequantize(qt, jnp.float32)

    for sp in SPARSITIES:
        if sp == 0.0:
            t = jnp.zeros(())
        else:
            t = jnp.quantile(jnp.abs(v_full), sp)
        # wall-clock of the fused kernel path (interpret mode, CPU)
        y = ops.floe_expert_gemv(x, qt, wg, wd, t)  # warm
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(trials):
            y = ops.floe_expert_gemv(x, qt, wg, wd, t)
        jax.block_until_ready(y)
        wall_us = (time.perf_counter() - t0) / trials * 1e6
        derived = {g: derived_latency_us(4096, 14336, sp, g) for g in GPUS}
        csv_rows.append((f"table1/sparse_kernel/sparsity={sp:.1f}",
                         wall_us,
                         ";".join(f"{g}={v:.0f}us" for g, v in derived.items())))
    # speedup trend (paper: >=1.26x @50%, >=1.44x @70%, ~2x @90% on 3090)
    base = derived_latency_us(4096, 14336, 0.0, "RTX-3090")
    for sp in (0.5, 0.7, 0.9):
        csv_rows.append((f"table1/speedup_3090/sparsity={sp:.1f}",
                         derived_latency_us(4096, 14336, sp, "RTX-3090"),
                         f"speedup={base / derived_latency_us(4096, 14336, sp, 'RTX-3090'):.2f}x"))
